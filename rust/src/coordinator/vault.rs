//! Durable multi-generation checkpoint store.
//!
//! A [`CheckpointVault`] owns every byte a checkpoint writes or resumes
//! from. With `keep = 1` (the default everywhere) it degrades to exactly
//! the historical single-file discipline — the payload JSON is written
//! verbatim to `<path>` via unique-temp + atomic rename, bit-identical
//! to pre-vault builds. With `keep > 1` each snapshot becomes a new
//! **generation** `<path>.g<N>`: a framed file whose fixed-width header
//! carries the codec version, the completed round, the payload length,
//! a CRC64 of the config fingerprint and a CRC64 of the payload. The
//! vault retains the newest `keep` generations and evicts the rest.
//!
//! ```text
//! TITANVLT1 vvvv rrrrrrrrrrrrrrrrrrrr llllllllllllllllllll ffff…16 cccc…16\n
//! <payload JSON, exactly l bytes>
//! ```
//!
//! [`CheckpointVault::load_latest_valid`] walks generations newest →
//! oldest and rejects anything torn (truncated, bad magic, length
//! mismatch), bit-flipped (payload CRC mismatch) or inconsistent
//! (header round / fingerprint hash disagreeing with the payload) —
//! closing the silent-wrong-resume hole where a flipped digit inside
//! still-valid JSON resumed from corrupted params without any error.
//! Every single-byte corruption of a frame is rejectable: the payload
//! is covered by CRC64, and each header field is cross-checked against
//! the payload it describes. A legacy unframed `<path>` file acts as
//! the final fallback generation (number 0) and is passed through
//! unvalidated so the caller's typed parse errors stay exactly as they
//! were.
//!
//! The walk's outcome is summarized in [`RecoveryTelemetry`]; a
//! degraded load (any rejected frame, or an older generation winning)
//! surfaces in `RunRecord`/`FleetRecord` and fires
//! [`FleetObserver::on_recovery`](crate::coordinator::host::FleetObserver::on_recovery).
//!
//! [`inject_corruption`] is the fault plane's one tested seam for
//! damaging checkpoint artifacts on disk: all four corruption kinds
//! ([`FaultKind::CorruptCheckpoint`], [`FaultKind::TornWrite`],
//! [`FaultKind::BitFlip`], [`FaultKind::StaleRename`]) are expressed
//! through it, seeded per `(session, round)` like the rest of
//! [`crate::fault::FaultPlan`].

use std::path::{Path, PathBuf};

use crate::fault::FaultKind;
use crate::util::durable_io;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// Vault frame codec version (bumped on incompatible header changes).
pub const VAULT_VERSION: usize = 1;

/// Frame magic: identifies a vault generation file.
const FRAME_MAGIC: &str = "TITANVLT1";

/// Fixed header size: magic(9) + sp + version(4) + sp + round(20) + sp
/// + payload_len(20) + sp + fingerprint_crc(16) + sp + payload_crc(16)
/// + newline.
const HEADER_LEN: usize = 91;

// ---- CRC64 ----------------------------------------------------------------

/// CRC-64/XZ (reflected, poly 0x42F0E1EBA9EA3693): the frame checksum.
/// Table-driven; the table is built at compile time, no dependencies.
const CRC64_TABLE: [u64; 256] = {
    let poly: u64 = 0xC96C_5795_D787_0F42; // reflected form
    let mut table = [0u64; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u64;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 == 1 { (crc >> 1) ^ poly } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// CRC64 of `bytes` (CRC-64/XZ parameters).
pub fn crc64(bytes: &[u8]) -> u64 {
    let mut crc = !0u64;
    for &b in bytes {
        crc = CRC64_TABLE[((crc ^ b as u64) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

// ---- telemetry ------------------------------------------------------------

/// What a [`CheckpointVault::load_latest_valid`] walk saw: how many
/// frames it scanned, how many it rejected and why, which generation
/// finally resumed, and how many completed rounds the rejected newer
/// frames claimed beyond it.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryTelemetry {
    /// On-disk artifacts examined (framed generations + legacy file).
    pub frames_scanned: u64,
    /// Frames rejected on a checksum / cross-check mismatch (payload
    /// CRC, fingerprint hash, or header round disagreeing with payload).
    pub crc_failures: u64,
    /// Frames rejected structurally: truncated, bad magic/layout, or a
    /// payload shorter than the header's declared length.
    pub torn_frames: u64,
    /// Generation number that resumed (0 = the legacy unframed file).
    pub generation_used: u64,
    /// Completed rounds claimed by readable-but-rejected newer frames
    /// beyond the generation used (0 when the newest frame won).
    pub rounds_lost: u64,
}

impl RecoveryTelemetry {
    /// True when the walk rejected anything or lost rounds — i.e. when
    /// this load is worth surfacing in records and observers.
    pub fn degraded(&self) -> bool {
        self.crc_failures > 0 || self.torn_frames > 0 || self.rounds_lost > 0
    }

    /// Fleet aggregation: counters sum, `generation_used` keeps the max.
    pub fn merge(&mut self, other: &RecoveryTelemetry) {
        self.frames_scanned += other.frames_scanned;
        self.crc_failures += other.crc_failures;
        self.torn_frames += other.torn_frames;
        self.generation_used = self.generation_used.max(other.generation_used);
        self.rounds_lost += other.rounds_lost;
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("frames_scanned", Json::Num(self.frames_scanned as f64)),
            ("crc_failures", Json::Num(self.crc_failures as f64)),
            ("torn_frames", Json::Num(self.torn_frames as f64)),
            ("generation_used", Json::Num(self.generation_used as f64)),
            ("rounds_lost", Json::Num(self.rounds_lost as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RecoveryTelemetry> {
        Ok(RecoveryTelemetry {
            frames_scanned: j.get("frames_scanned")?.as_usize()? as u64,
            crc_failures: j.get("crc_failures")?.as_usize()? as u64,
            torn_frames: j.get("torn_frames")?.as_usize()? as u64,
            generation_used: j.get("generation_used")?.as_usize()? as u64,
            rounds_lost: j.get("rounds_lost")?.as_usize()? as u64,
        })
    }
}

// ---- frame codec ----------------------------------------------------------

/// Why a frame was rejected; maps onto the two telemetry counters.
enum FrameReject {
    /// Structural: truncation, bad magic/layout, length mismatch.
    Torn(String),
    /// Content: checksum or header↔payload cross-check mismatch.
    Crc(String),
}

fn encode_frame(round: usize, fingerprint: &str, payload: &str) -> String {
    let mut frame = format!(
        "{} {:04} {:020} {:020} {:016x} {:016x}\n",
        FRAME_MAGIC,
        VAULT_VERSION,
        round,
        payload.len(),
        crc64(fingerprint.as_bytes()),
        crc64(payload.as_bytes()),
    );
    debug_assert_eq!(frame.len(), HEADER_LEN);
    frame.push_str(payload);
    frame
}

fn field_usize(bytes: &[u8], what: &str) -> std::result::Result<usize, FrameReject> {
    std::str::from_utf8(bytes)
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .ok_or_else(|| FrameReject::Torn(format!("unparsable {what} field")))
}

fn field_hex(bytes: &[u8], what: &str) -> std::result::Result<u64, FrameReject> {
    std::str::from_utf8(bytes)
        .ok()
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .ok_or_else(|| FrameReject::Torn(format!("unparsable {what} field")))
}

/// Validate one frame end-to-end; returns the payload text and the
/// round both the header and the payload agree on.
fn decode_frame(bytes: &[u8]) -> std::result::Result<(String, usize), FrameReject> {
    if bytes.len() < HEADER_LEN {
        return Err(FrameReject::Torn(format!(
            "{} bytes, shorter than the {HEADER_LEN}-byte frame header",
            bytes.len()
        )));
    }
    if &bytes[..9] != FRAME_MAGIC.as_bytes() {
        return Err(FrameReject::Torn("bad frame magic".into()));
    }
    for &sep in &[9usize, 14, 35, 56, 73] {
        if bytes[sep] != b' ' {
            return Err(FrameReject::Torn("malformed frame header layout".into()));
        }
    }
    if bytes[HEADER_LEN - 1] != b'\n' {
        return Err(FrameReject::Torn("malformed frame header layout".into()));
    }
    let version = field_usize(&bytes[10..14], "version")?;
    if version != VAULT_VERSION {
        return Err(FrameReject::Torn(format!(
            "unsupported vault codec version {version} (this build reads {VAULT_VERSION})"
        )));
    }
    let round = field_usize(&bytes[15..35], "round")?;
    let payload_len = field_usize(&bytes[36..56], "payload length")?;
    let fp_crc = field_hex(&bytes[57..73], "fingerprint crc")?;
    let payload_crc = field_hex(&bytes[74..90], "payload crc")?;
    let payload = &bytes[HEADER_LEN..];
    if payload.len() != payload_len {
        return Err(FrameReject::Torn(format!(
            "payload is {} bytes, header declares {payload_len}",
            payload.len()
        )));
    }
    if crc64(payload) != payload_crc {
        return Err(FrameReject::Crc("payload CRC64 mismatch".into()));
    }
    // the checksum passed, so the payload is the writer's bytes; the
    // remaining checks catch a corrupted *header* on an intact payload
    let text = String::from_utf8(payload.to_vec())
        .map_err(|_| FrameReject::Crc("payload is not UTF-8".into()))?;
    let j = Json::parse(&text)
        .map_err(|e| FrameReject::Crc(format!("payload is not valid JSON: {e}")))?;
    let payload_round = j
        .get("round")
        .and_then(|r| r.as_usize())
        .map_err(|e| FrameReject::Crc(format!("payload carries no round: {e}")))?;
    if payload_round != round {
        return Err(FrameReject::Crc(format!(
            "header claims round {round}, payload says {payload_round}"
        )));
    }
    let config = j
        .get("config")
        .map_err(|e| FrameReject::Crc(format!("payload carries no config: {e}")))?;
    if crc64(config.to_string_compact().as_bytes()) != fp_crc {
        return Err(FrameReject::Crc(
            "header fingerprint hash disagrees with the payload config".into(),
        ));
    }
    Ok((text, round))
}

/// The round a frame's header claims, if the header alone is readable —
/// used to count `rounds_lost` across rejected frames.
fn header_claimed_round(bytes: &[u8]) -> Option<usize> {
    if bytes.len() < HEADER_LEN || &bytes[..9] != FRAME_MAGIC.as_bytes() {
        return None;
    }
    field_usize(&bytes[15..35], "round").ok()
}

// ---- the vault ------------------------------------------------------------

/// The winning artifact of a [`CheckpointVault::load_latest_valid`]
/// walk. `generation == 0` means the legacy unframed `<path>` file,
/// whose `text` is passed through unvalidated (the caller's checkpoint
/// parser keeps its historical typed errors).
#[derive(Debug)]
pub struct ValidGeneration {
    /// The checkpoint payload JSON.
    pub text: String,
    /// Round the frame header claims (0 for an unvalidated legacy file
    /// whose payload could not be probed).
    pub round: usize,
    /// Generation number (0 = legacy file).
    pub generation: usize,
    /// The on-disk artifact the text came from.
    pub path: PathBuf,
}

/// Durable multi-generation checkpoint store — see the module docs.
#[derive(Clone, Debug)]
pub struct CheckpointVault {
    path: PathBuf,
    keep: usize,
}

impl CheckpointVault {
    /// A vault rooted at `path`, retaining the newest `keep` (≥ 1)
    /// generations. `keep == 1` writes the bare payload to `path`
    /// itself, byte-identical to the pre-vault single-file discipline.
    /// Construction sweeps temp files earlier incarnations orphaned.
    pub fn new(path: impl Into<PathBuf>, keep: usize) -> CheckpointVault {
        assert!(keep >= 1, "a vault must keep at least one generation");
        let path = path.into();
        durable_io::sweep_stale_tmp(&path);
        CheckpointVault { path, keep }
    }

    /// The vault's base path (`<path>` / `<path>.g<N>`).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Generations retained on write.
    pub fn keep(&self) -> usize {
        self.keep
    }

    /// Whether anything on disk could be resumed from.
    pub fn has_artifacts(&self) -> bool {
        self.path.exists() || !self.generations().is_empty()
    }

    /// Framed generation files next to `path`, newest first.
    fn generations(&self) -> Vec<(usize, PathBuf)> {
        let (Some(dir), Some(stem)) = (self.path.parent(), self.path.file_name()) else {
            return Vec::new();
        };
        let Some(stem) = stem.to_str() else { return Vec::new() };
        let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
        let Ok(entries) = std::fs::read_dir(dir) else { return Vec::new() };
        let mut gens: Vec<(usize, PathBuf)> = entries
            .flatten()
            .filter_map(|entry| {
                let name = entry.file_name();
                let name = name.to_str()?;
                let suffix = name.strip_prefix(stem)?.strip_prefix(".g")?;
                let n: usize = suffix.parse().ok()?;
                Some((n, entry.path()))
            })
            .collect();
        gens.sort_unstable_by(|a, b| b.0.cmp(&a.0));
        gens
    }

    /// Persist one snapshot. `fingerprint` is the payload's compact
    /// config serialization (`config.to_string_compact()`); the frame
    /// header cross-checks against it on load. Atomic either way; the
    /// caller decides what a failure costs (the `Checkpoint` observer
    /// counts and logs, never aborts the run it protects).
    pub fn write(&self, round: usize, fingerprint: &str, payload: &str) -> std::io::Result<()> {
        if self.keep == 1 {
            durable_io::write_atomic(&self.path, payload.as_bytes())?;
            // a vault shrunk back to keep=1 must not leave newer-looking
            // framed generations shadowing the file it now writes
            for (_, p) in self.generations() {
                // detlint: allow(R002) best-effort eviction; a survivor is re-evicted next write
                let _ = std::fs::remove_file(p);
            }
            return Ok(());
        }
        let next = self.generations().first().map_or(1, |(n, _)| n + 1);
        let gen_path = self.generation_path(next);
        let frame = encode_frame(round, fingerprint, payload);
        durable_io::write_atomic(&gen_path, frame.as_bytes())?;
        for (_, p) in self.generations().into_iter().skip(self.keep) {
            // detlint: allow(R002) best-effort eviction; a survivor is re-evicted next write
            let _ = std::fs::remove_file(p);
        }
        Ok(())
    }

    /// `<path>.g<N>`.
    pub fn generation_path(&self, n: usize) -> PathBuf {
        let mut name = self.path.as_os_str().to_owned();
        name.push(format!(".g{n}"));
        PathBuf::from(name)
    }

    /// Walk newest → oldest and return the first artifact that survives
    /// validation, plus the [`RecoveryTelemetry`] of the whole walk
    /// (also returned alongside the error when nothing survived). The
    /// legacy unframed `<path>` is the final, pass-through fallback.
    pub fn load_latest_valid(&self) -> (Result<ValidGeneration>, RecoveryTelemetry) {
        let mut telemetry = RecoveryTelemetry::default();
        let mut max_claimed: Option<usize> = None;
        let mut first_reject: Option<String> = None;
        for (n, path) in self.generations() {
            telemetry.frames_scanned += 1;
            let bytes = match std::fs::read(&path) {
                Ok(b) => b,
                Err(e) => {
                    telemetry.torn_frames += 1;
                    first_reject.get_or_insert(format!("{}: read: {e}", path.display()));
                    continue;
                }
            };
            if let Some(r) = header_claimed_round(&bytes) {
                max_claimed = Some(max_claimed.map_or(r, |m: usize| m.max(r)));
            }
            match decode_frame(&bytes) {
                Ok((text, round)) => {
                    telemetry.generation_used = n as u64;
                    telemetry.rounds_lost =
                        max_claimed.map_or(0, |m| m.saturating_sub(round)) as u64;
                    return (
                        Ok(ValidGeneration { text, round, generation: n, path }),
                        telemetry,
                    );
                }
                Err(FrameReject::Torn(detail)) => {
                    telemetry.torn_frames += 1;
                    first_reject.get_or_insert(format!("{}: {detail}", path.display()));
                }
                Err(FrameReject::Crc(detail)) => {
                    telemetry.crc_failures += 1;
                    first_reject.get_or_insert(format!("{}: {detail}", path.display()));
                }
            }
        }
        if self.path.exists() {
            telemetry.frames_scanned += 1;
            match std::fs::read_to_string(&self.path) {
                Ok(text) => {
                    // pass-through: the caller's parser owns validation
                    // (and its historical typed errors) for legacy files
                    let round = Json::parse(&text)
                        .ok()
                        .and_then(|j| j.get("round").and_then(|r| r.as_usize()).ok())
                        .unwrap_or(0);
                    telemetry.generation_used = 0;
                    telemetry.rounds_lost =
                        max_claimed.map_or(0, |m| m.saturating_sub(round)) as u64;
                    return (
                        Ok(ValidGeneration {
                            text,
                            round,
                            generation: 0,
                            path: self.path.clone(),
                        }),
                        telemetry,
                    );
                }
                Err(e) => {
                    telemetry.torn_frames += 1;
                    first_reject.get_or_insert(format!("{}: read: {e}", self.path.display()));
                }
            }
        }
        let detail = first_reject
            .unwrap_or_else(|| "no checkpoint artifact on disk".into());
        let err = Error::Checkpoint {
            path: self.path.display().to_string(),
            stage: "vault",
            detail: format!(
                "no valid generation ({} scanned, {} torn, {} checksum failures): {detail}",
                telemetry.frames_scanned, telemetry.torn_frames, telemetry.crc_failures
            ),
        };
        (Err(err), telemetry)
    }
}

// ---- fault injection seam -------------------------------------------------

/// Damage the newest on-disk checkpoint artifact of the vault rooted at
/// `base` — the single tested seam every checkpoint-corruption fault
/// goes through. Deterministic in `seed` (derive it per `(session,
/// round)` via [`crate::fault::FaultPlan::corruption_seed`]). Non-
/// corruption kinds are a no-op. Best-effort like a real bad disk:
/// failures are logged, never propagated.
pub fn inject_corruption(kind: &FaultKind, base: &Path, seed: u64) {
    let probe = CheckpointVault::new(base, 1);
    let gens = probe.generations();
    let target = gens
        .first()
        .map(|(_, p)| p.clone())
        .or_else(|| base.exists().then(|| base.to_path_buf()));
    let Some(target) = target else {
        log::warn!("fault: no checkpoint artifact to corrupt at {}", base.display());
        return;
    };
    let result = apply_corruption(kind, &target, gens.get(1).map(|(_, p)| p.as_path()), seed);
    if let Err(e) = result {
        log::warn!("fault: failed to corrupt checkpoint {}: {e}", target.display());
    }
}

fn apply_corruption(
    kind: &FaultKind,
    target: &Path,
    previous: Option<&Path>,
    seed: u64,
) -> std::io::Result<()> {
    use std::io::{Seek, SeekFrom, Write};
    let len = std::fs::metadata(target)?.len();
    let open = || std::fs::OpenOptions::new().write(true).open(target);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    match kind {
        // historical behavior, preserved bit-for-bit: clip to half
        FaultKind::CorruptCheckpoint => open()?.set_len(len / 2),
        // a write the power failed mid-way through: a seeded prefix
        FaultKind::TornWrite => {
            let cut = if len == 0 { 0 } else { rng.state()[0] % len };
            open()?.set_len(cut)
        }
        // silent media corruption: one seeded bit, anywhere in the file
        FaultKind::BitFlip => {
            if len == 0 {
                return Ok(());
            }
            let offset = rng.state()[0] % len;
            let bit = (rng.state()[1] % 8) as u8;
            let mut bytes = std::fs::read(target)?;
            bytes[offset as usize] ^= 1 << bit;
            let mut f = open()?;
            f.seek(SeekFrom::Start(offset))?;
            f.write_all(&bytes[offset as usize..offset as usize + 1])
        }
        // a rename that resurrected the previous generation's bytes
        FaultKind::StaleRename => match previous {
            Some(prev) => std::fs::copy(prev, target).map(|_| ()),
            None => open()?.set_len(0),
        },
        _ => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(round: usize, seed: usize) -> String {
        Json::obj(vec![
            ("titan_checkpoint", Json::Num(1.0)),
            ("round", Json::Num(round as f64)),
            ("config", Json::obj(vec![("seed", Json::Num(seed as f64))])),
            ("params", Json::from_f64s(&[0.5, -0.25, 1.0e-7])),
        ])
        .to_string_compact()
    }

    fn fingerprint(seed: usize) -> String {
        Json::obj(vec![("seed", Json::Num(seed as f64))]).to_string_compact()
    }

    fn fresh_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn crc64_matches_the_reference_vector() {
        // CRC-64/XZ check value
        assert_eq!(crc64(b"123456789"), 0x995D_C9BB_DF19_39FA);
        assert_eq!(crc64(b""), 0);
        assert_ne!(crc64(b"a"), crc64(b"b"));
    }

    #[test]
    fn frame_roundtrips_exactly() {
        let p = payload(7, 3);
        let frame = encode_frame(7, &fingerprint(3), &p);
        assert_eq!(frame.len(), HEADER_LEN + p.len());
        let (text, round) = match decode_frame(frame.as_bytes()) {
            Ok(ok) => ok,
            Err(FrameReject::Torn(d)) | Err(FrameReject::Crc(d)) => panic!("rejected: {d}"),
        };
        assert_eq!(text, p);
        assert_eq!(round, 7);
    }

    /// The tentpole's property sweep: every prefix truncation and every
    /// single-byte corruption of a frame is rejected — a frame never
    /// decodes to a different state than the one written.
    #[test]
    fn every_truncation_and_single_byte_corruption_is_rejected() {
        let p = payload(12, 9);
        let frame = encode_frame(12, &fingerprint(9), &p).into_bytes();
        for cut in 0..frame.len() {
            assert!(
                decode_frame(&frame[..cut]).is_err(),
                "prefix truncation at {cut}/{} decoded",
                frame.len()
            );
        }
        for pos in 0..frame.len() {
            for delta in [0x01u8, 0x80, 0xFF] {
                let mut bad = frame.clone();
                bad[pos] ^= delta;
                match decode_frame(&bad) {
                    Err(_) => {}
                    Ok((text, _)) => panic!(
                        "byte {pos} ^ {delta:#x} decoded to {} bytes of payload",
                        text.len()
                    ),
                }
            }
        }
    }

    /// `keep = 1` is byte-identical to the historical single-file path:
    /// the payload lands verbatim at `<path>` and no `.g` files appear.
    #[test]
    fn keep_one_writes_the_bare_payload() {
        let dir = fresh_dir("titan_vault_keep1");
        let vault = CheckpointVault::new(dir.join("ck.json"), 1);
        let p = payload(4, 1);
        vault.write(4, &fingerprint(1), &p).unwrap();
        assert_eq!(std::fs::read_to_string(dir.join("ck.json")).unwrap(), p);
        assert!(vault.generations().is_empty());
        let (win, t) = vault.load_latest_valid();
        let win = win.unwrap();
        assert_eq!(win.generation, 0);
        assert_eq!(win.round, 4);
        assert_eq!(win.text, p);
        assert!(!t.degraded());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Generation-ring rotation and eviction roundtrip: six writes at
    /// keep=3 retain exactly the newest three generations, and the walk
    /// resumes from the newest with clean telemetry.
    #[test]
    fn generation_ring_rotates_and_evicts() {
        let dir = fresh_dir("titan_vault_ring");
        let vault = CheckpointVault::new(dir.join("ck.json"), 3);
        for round in 1..=6usize {
            vault.write(round, &fingerprint(1), &payload(round, 1)).unwrap();
        }
        let gens: Vec<usize> = vault.generations().iter().map(|(n, _)| *n).collect();
        assert_eq!(gens, vec![6, 5, 4], "newest three generations retained");
        assert!(!vault.path().exists(), "keep>1 never writes the bare path");
        let (win, t) = vault.load_latest_valid();
        let win = win.unwrap();
        assert_eq!((win.generation, win.round), (6, 6));
        assert_eq!(win.text, payload(6, 1));
        assert_eq!(
            t,
            RecoveryTelemetry {
                frames_scanned: 1,
                generation_used: 6,
                ..RecoveryTelemetry::default()
            }
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The fallback chain: a torn newest generation loses to its valid
    /// predecessor, with the telemetry counting the rejected frame and
    /// the rounds the torn frame claimed beyond the survivor.
    #[test]
    fn torn_newest_generation_falls_back_to_previous() {
        let dir = fresh_dir("titan_vault_fallback");
        let vault = CheckpointVault::new(dir.join("ck.json"), 3);
        vault.write(2, &fingerprint(1), &payload(2, 1)).unwrap();
        vault.write(5, &fingerprint(1), &payload(5, 1)).unwrap();
        // tear the newest frame mid-payload
        let newest = vault.generation_path(2);
        let len = std::fs::metadata(&newest).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&newest)
            .unwrap()
            .set_len(len - 10)
            .unwrap();
        let (win, t) = vault.load_latest_valid();
        let win = win.unwrap();
        assert_eq!((win.generation, win.round), (1, 2));
        assert_eq!(
            t,
            RecoveryTelemetry {
                frames_scanned: 2,
                torn_frames: 1,
                generation_used: 1,
                rounds_lost: 3,
            }
        );
        assert!(t.degraded());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A bit flip inside the payload is a CRC failure, not a torn frame.
    #[test]
    fn bit_flipped_payload_counts_as_crc_failure() {
        let dir = fresh_dir("titan_vault_bitflip");
        let vault = CheckpointVault::new(dir.join("ck.json"), 2);
        vault.write(1, &fingerprint(1), &payload(1, 1)).unwrap();
        vault.write(3, &fingerprint(1), &payload(3, 1)).unwrap();
        let newest = vault.generation_path(2);
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = HEADER_LEN + (bytes.len() - HEADER_LEN) / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&newest, &bytes).unwrap();
        let (win, t) = vault.load_latest_valid();
        assert_eq!(win.unwrap().round, 1);
        assert_eq!(t.crc_failures, 1);
        assert_eq!(t.torn_frames, 0);
        assert_eq!(t.rounds_lost, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A wrong-fingerprint frame (header hash disagreeing with the
    /// payload config) is rejected even though its JSON parses fine —
    /// the silent-wrong-resume hole this vault exists to close.
    #[test]
    fn wrong_fingerprint_frame_is_rejected() {
        let dir = fresh_dir("titan_vault_fp");
        let vault = CheckpointVault::new(dir.join("ck.json"), 2);
        vault.write(2, &fingerprint(1), &payload(2, 1)).unwrap();
        // forge a newer frame whose header hash belongs to another config
        let forged = encode_frame(4, &fingerprint(99), &payload(4, 1));
        std::fs::write(vault.generation_path(2), forged).unwrap();
        let (win, t) = vault.load_latest_valid();
        assert_eq!(win.unwrap().round, 2);
        assert_eq!(t.crc_failures, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A legacy unframed file is the final fallback and passes through
    /// unvalidated — even when every framed generation is rejected.
    #[test]
    fn legacy_file_is_the_final_fallback() {
        let dir = fresh_dir("titan_vault_legacy");
        let base = dir.join("ck.json");
        std::fs::write(&base, payload(3, 1)).unwrap();
        let vault = CheckpointVault::new(&base, 3);
        let (win, t) = vault.load_latest_valid();
        let win = win.unwrap();
        assert_eq!((win.generation, win.round), (0, 3));
        assert!(!t.degraded());
        // now shadow it with a frame, then tear the frame: back to legacy
        vault.write(5, &fingerprint(1), &payload(5, 1)).unwrap();
        std::fs::write(vault.generation_path(1), b"TITANVLT1 garbage").unwrap();
        let (win, t) = vault.load_latest_valid();
        assert_eq!(win.unwrap().generation, 0);
        assert_eq!(t.torn_frames, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Nothing valid on disk: the walk errors with a typed checkpoint
    /// error carrying the rejection tallies, and the telemetry matches.
    #[test]
    fn exhausted_vault_yields_typed_error_and_telemetry() {
        let dir = fresh_dir("titan_vault_exhausted");
        let vault = CheckpointVault::new(dir.join("ck.json"), 2);
        vault.write(2, &fingerprint(1), &payload(2, 1)).unwrap();
        std::fs::write(vault.generation_path(1), b"short").unwrap();
        let (win, t) = vault.load_latest_valid();
        match win {
            Err(Error::Checkpoint { stage: "vault", detail, .. }) => {
                assert!(detail.contains("1 torn"), "{detail}");
            }
            other => panic!("expected vault-stage error, got {other:?}"),
        }
        assert_eq!(t.torn_frames, 1);
        assert_eq!(t.frames_scanned, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The injector seam is deterministic in its seed and damages the
    /// newest artifact for every corruption kind.
    #[test]
    fn corruption_injection_is_deterministic_per_seed() {
        for kind in [
            FaultKind::CorruptCheckpoint,
            FaultKind::TornWrite,
            FaultKind::BitFlip,
            FaultKind::StaleRename,
        ] {
            let mut damaged = Vec::new();
            for copy in 0..2 {
                let dir = fresh_dir(&format!("titan_vault_inject_{}_{copy}", kind.name()));
                let vault = CheckpointVault::new(dir.join("ck.json"), 2);
                vault.write(2, &fingerprint(1), &payload(2, 1)).unwrap();
                vault.write(4, &fingerprint(1), &payload(4, 1)).unwrap();
                inject_corruption(&kind, &dir.join("ck.json"), 0xABCD);
                let bytes = std::fs::read(vault.generation_path(2)).unwrap();
                assert_ne!(
                    bytes,
                    encode_frame(4, &fingerprint(1), &payload(4, 1)).into_bytes(),
                    "{} left the newest frame intact",
                    kind.name()
                );
                // the older generation is never touched
                assert_eq!(
                    std::fs::read(vault.generation_path(1)).unwrap(),
                    encode_frame(2, &fingerprint(1), &payload(2, 1)).into_bytes()
                );
                // and the walk still recovers something
                let (win, t) = vault.load_latest_valid();
                match kind {
                    // a stale rename resurrects a valid (older) frame
                    FaultKind::StaleRename => assert_eq!(win.unwrap().round, 2),
                    _ => {
                        assert_eq!(win.unwrap().round, 2);
                        assert!(t.degraded(), "{}: {t:?}", kind.name());
                    }
                }
                damaged.push(bytes);
                let _ = std::fs::remove_dir_all(&dir);
            }
            assert_eq!(damaged[0], damaged[1], "{} is not deterministic", kind.name());
        }
    }

    #[test]
    fn recovery_telemetry_json_roundtrip_and_merge() {
        let t = RecoveryTelemetry {
            frames_scanned: 3,
            crc_failures: 1,
            torn_frames: 1,
            generation_used: 4,
            rounds_lost: 2,
        };
        let back = RecoveryTelemetry::from_json(&Json::parse(
            &t.to_json().to_string_compact(),
        ).unwrap())
        .unwrap();
        assert_eq!(back, t);
        let mut sum = RecoveryTelemetry::default();
        sum.merge(&t);
        sum.merge(&t);
        assert_eq!(sum.frames_scanned, 6);
        assert_eq!(sum.rounds_lost, 4);
        assert_eq!(sum.generation_used, 4);
    }
}
