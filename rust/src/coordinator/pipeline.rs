//! Pipelined coordinator — the paper's §3.4 design.
//!
//! Two OS threads model the two device compute lanes:
//!
//! - **selector thread** (the paper's GPU processes 1+2): pulls the
//!   stream, runs the coarse filter + fine selection, ships the batch for
//!   the NEXT round over a channel.
//! - **trainer thread** (the paper's CPU process 3, here the caller's
//!   thread): trains on the batch selected in the PREVIOUS round, ships
//!   fresh parameters back.
//!
//! The "one-round-delay" scheme falls out of the channel topology: while
//! the trainer updates `w_t` with batch `B_t` (chosen under `w_{t-1}`),
//! the selector is already choosing `B_{t+1}` under `w_{t-1}`/`w_t` —
//! whichever sync arrived last.
//!
//! Handoff is zero-copy in both directions. Each `ModelRuntime` is
//! thread-local (PJRT client is !Send), so only ownership crosses
//! threads:
//!
//! - **params** (trainer → selector): an `Arc<Vec<f32>>` snapshot through
//!   a latest-only slot ([`crate::util::sync::Latest`]) — bounded with
//!   overwrite semantics, so a lagging selector never queues stale
//!   parameter copies (the old unbounded `mpsc::channel` grew with the
//!   lag) and never costs the trainer a `Vec` clone per round.
//! - **batches** (selector → trainer): the `TrainBatch` is *moved* over a
//!   `sync_channel(1)`. Batches — unlike params — must all be consumed in
//!   round order (the one-round-delay contract), so a bounded channel, not
//!   a latest-only slot, is the right shape; the samples' payloads are
//!   `Arc`-shared so the move is pointer-sized per sample.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::config::RunConfig;
use crate::coordinator::{build_stream, RoundOutcome, SelectorEngine, SelectorReport, TrainerEngine};
use crate::device::idle::IdleTrace;
use crate::device::{memory, DeviceSim, Lane, Op};
use crate::metrics::{CurvePoint, RunRecord};
use crate::util::sync::Latest;
use crate::util::timer::Stopwatch;
use crate::{Error, Result};

/// Message from the selector thread to the trainer per round.
struct SelectedBatch {
    round: usize,
    batch: crate::coordinator::TrainBatch,
    report: SelectorReport,
}

/// Run a pipelined training run; returns the run record and per-round
/// outcomes. `idle` governs the per-round candidate budget (Fig. 9).
pub fn run_with_idle(cfg: &RunConfig, idle: IdleTrace) -> Result<(RunRecord, Vec<RoundOutcome>)> {
    cfg.validate()?;
    let (mut stream, test) = build_stream(cfg);
    let task = stream.task().clone();
    let rounds = cfg.rounds;

    // batches forward over a bounded channel (round-ordered, moved);
    // params backward through a latest-only slot (Arc snapshot, overwrite)
    let (batch_tx, batch_rx) = mpsc::sync_channel::<Result<SelectedBatch>>(1);
    let param_slot: Arc<Latest<Arc<Vec<f32>>>> = Arc::new(Latest::new());
    let selector_params = Arc::clone(&param_slot);

    // ---- selector thread ----------------------------------------------------
    let sel_cfg = cfg.clone();
    let selector_handle = thread::Builder::new()
        .name("titan-selector".into())
        .spawn(move || -> Result<()> {
            let mut selector = SelectorEngine::new(&sel_cfg, &task)?;
            selector.idle = idle;
            // select one batch per round, rounds+0..rounds (the batch for
            // round r is selected during round r-1's training window)
            for round in 0..rounds {
                // adopt the freshest params the trainer has shipped
                // (non-blocking: one-round-delay tolerates staleness; the
                // slot holds at most the newest snapshot, no drain loop)
                if let Some(p) = selector_params.take() {
                    selector.sync_params(p)?;
                }
                let arrivals = stream.next_round(sel_cfg.stream_per_round);
                let out = selector
                    .select_round(round, arrivals)
                    .map(|(batch, report)| SelectedBatch { round, batch, report });
                let failed = out.is_err();
                if batch_tx.send(out).is_err() || failed {
                    break; // trainer hung up or selection failed
                }
            }
            Ok(())
        })
        .map_err(|e| Error::Pipeline(format!("spawn selector: {e}")))?;

    // ---- trainer (this thread) ------------------------------------------------
    let mut trainer = TrainerEngine::new(cfg)?;
    let mut sim = DeviceSim::new(&cfg.model);
    let mut record = RunRecord::new(cfg.method.name(), &cfg.model);
    let mut outcomes = Vec::with_capacity(rounds);
    let run_sw = Stopwatch::start();

    for round in 0..rounds {
        let sel = batch_rx
            .recv()
            .map_err(|_| Error::Pipeline("selector thread terminated".into()))??;
        debug_assert_eq!(sel.round, round);
        for &op in &sel.report.ops {
            sim.record(Lane::Gpu, op);
        }
        record
            .processing_delay
            .record_ms(sel.report.per_sample_host_ms);

        let (loss, train_ms) = trainer.train_batch(&sel.batch)?;
        sim.record(Lane::Cpu, Op::TrainStep { batch: sel.batch.len() });
        sim.record(Lane::Gpu, Op::Sync); // params + batch handoff
        let timing = sim.end_round(true); // pipelined: lanes overlap

        // ship a zero-copy param snapshot to the selector (overwrite any
        // unconsumed one — the selector only ever wants the newest)
        param_slot.publish(trainer.share_params());

        record.round_device_ms.push(timing.wall_ms);
        record.round_host_ms.push(train_ms.max(sel.report.host_ms));
        outcomes.push(RoundOutcome {
            round,
            train_loss: loss,
            train_host_ms: train_ms,
            selector: sel.report,
            device_wall_ms: timing.wall_ms,
            device_cpu_ms: timing.cpu_ms,
            device_gpu_ms: timing.gpu_ms,
        });

        if cfg.eval_every > 0 && (round + 1) % cfg.eval_every == 0 {
            let rep = trainer.evaluate(&test)?;
            record.curve.push(CurvePoint {
                round: round + 1,
                device_ms: sim.total_ms(),
                host_ms: run_sw.elapsed_ms(),
                train_loss: loss as f64,
                test_loss: rep.loss,
                test_accuracy: rep.accuracy,
            });
        }
    }
    drop(batch_rx);
    selector_handle
        .join()
        .map_err(|_| Error::Pipeline("selector thread panicked".into()))??;

    let final_eval = trainer.evaluate(&test)?;
    record.final_accuracy = final_eval.accuracy;
    record.total_device_ms = sim.total_ms();
    record.total_host_ms = run_sw.elapsed_ms();
    record.energy_j = sim.energy().energy_j();
    record.avg_power_w = sim.energy().avg_power_w();
    let meta = &trainer.rt.set.meta;
    record.peak_memory_bytes = memory::estimate(
        meta.param_count,
        memory::act_mult_for(&cfg.model),
        cfg.batch_size,
        meta.input_dim,
        cfg.candidate_size,
        meta.cand_max,
        meta.feature_dim(cfg.filter_blocks),
        meta.filter_chunk,
        true,
    )
    .total();
    Ok((record, outcomes))
}

/// Run with a constant full idle capacity (the default).
pub fn run(cfg: &RunConfig) -> Result<(RunRecord, Vec<RoundOutcome>)> {
    run_with_idle(cfg, IdleTrace::Constant(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Method};

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny() -> RunConfig {
        let mut c = presets::table1("mlp", Method::Titan);
        c.rounds = 6;
        c.test_size = 200;
        c.eval_every = 3;
        c
    }

    #[test]
    fn pipeline_runs_and_overlaps() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let (record, outcomes) = run(&tiny()).unwrap();
        assert_eq!(outcomes.len(), 6);
        assert!(record.final_accuracy > 0.0);
        // device clock: pipelined wall = max(lanes), strictly below sum
        for o in &outcomes {
            assert!(o.device_wall_ms <= o.device_cpu_ms + o.device_gpu_ms - 1e-9);
            assert!(o.device_wall_ms >= o.device_cpu_ms.max(o.device_gpu_ms) - 1e-9);
        }
    }

    #[test]
    fn pipeline_beats_sequential_on_device_clock() {
        if !have_artifacts() {
            return;
        }
        let cfg = tiny();
        let (pipe, _) = run(&cfg).unwrap();
        let mut seq_cfg = cfg.clone();
        seq_cfg.pipeline = false;
        let (seq, _) = crate::coordinator::sequential::run(&seq_cfg).unwrap();
        assert!(
            pipe.total_device_ms < seq.total_device_ms,
            "pipe {} !< seq {}",
            pipe.total_device_ms,
            seq.total_device_ms
        );
    }

    #[test]
    fn idle_trace_shrinks_candidates() {
        if !have_artifacts() {
            return;
        }
        let cfg = tiny();
        let (_, outcomes) =
            run_with_idle(&cfg, IdleTrace::Constant(0.5)).unwrap();
        // budget = 0.5 * 30 = 15
        assert!(outcomes.iter().all(|o| o.selector.candidates <= 15));
    }

    #[test]
    fn one_round_delay_still_learns() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = tiny();
        cfg.rounds = 40;
        cfg.eval_every = 5;
        let (record, _) = run(&cfg).unwrap();
        // the one-round-delay scheme must not break learning: accuracy
        // well above chance (1/6) and clearly above the first checkpoint
        let first = record.curve.first().unwrap().test_accuracy;
        let best = record.best_accuracy();
        assert!(best > 0.4, "no learning through the pipeline: best {best}");
        assert!(best >= first, "accuracy regressed: {first} -> {best}");
    }
}
