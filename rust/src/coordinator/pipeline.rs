//! Pipelined coordinator — **deprecated thin shims** over the session
//! API ([`crate::coordinator::session`]).
//!
//! The paper's §3.4 design (two OS threads, one-round-delay batch
//! handoff over a bounded channel, zero-copy `Arc` parameter snapshots
//! through a latest-only slot) now lives in the session module's
//! `ExecBackend::Pipelined` backend; see its docs for the handoff
//! topology. These shims pin that backend for pre-session call sites.

use crate::config::RunConfig;
use crate::coordinator::session::SessionBuilder;
use crate::coordinator::RoundOutcome;
use crate::device::idle::IdleTrace;
use crate::metrics::RunRecord;
use crate::Result;

/// Run a pipelined training run; returns the run record and per-round
/// outcomes. `idle` governs the per-round candidate budget (Fig. 9).
#[deprecated(note = "use coordinator::session::SessionBuilder::new(cfg).pipelined(idle).run()")]
pub fn run_with_idle(cfg: &RunConfig, idle: IdleTrace) -> Result<(RunRecord, Vec<RoundOutcome>)> {
    SessionBuilder::new(cfg.clone()).pipelined(idle).run()
}

/// Run with a constant full idle capacity (the default).
#[deprecated(note = "use coordinator::session::SessionBuilder::new(cfg).pipelined(...).run()")]
pub fn run(cfg: &RunConfig) -> Result<(RunRecord, Vec<RoundOutcome>)> {
    SessionBuilder::new(cfg.clone())
        .pipelined(IdleTrace::Constant(1.0))
        .run()
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]

    use super::*;
    use crate::config::{presets, Method};

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny() -> RunConfig {
        let mut c = presets::table1("mlp", Method::Titan);
        c.rounds = 6;
        c.test_size = 200;
        c.eval_every = 3;
        c
    }

    #[test]
    fn pipeline_runs_and_overlaps() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let (record, outcomes) = run(&tiny()).unwrap();
        assert_eq!(outcomes.len(), 6);
        assert!(record.final_accuracy > 0.0);
        // device clock: pipelined wall = max(lanes), strictly below sum
        for o in &outcomes {
            assert!(o.device_wall_ms <= o.device_cpu_ms + o.device_gpu_ms - 1e-9);
            assert!(o.device_wall_ms >= o.device_cpu_ms.max(o.device_gpu_ms) - 1e-9);
        }
    }

    #[test]
    fn pipeline_beats_sequential_on_device_clock() {
        if !have_artifacts() {
            return;
        }
        let cfg = tiny();
        let (pipe, _) = run(&cfg).unwrap();
        let mut seq_cfg = cfg.clone();
        seq_cfg.pipeline = false;
        let (seq, _) = crate::coordinator::sequential::run(&seq_cfg).unwrap();
        assert!(
            pipe.total_device_ms < seq.total_device_ms,
            "pipe {} !< seq {}",
            pipe.total_device_ms,
            seq.total_device_ms
        );
    }

    #[test]
    fn idle_trace_shrinks_candidates() {
        if !have_artifacts() {
            return;
        }
        let cfg = tiny();
        let (_, outcomes) =
            run_with_idle(&cfg, IdleTrace::Constant(0.5)).unwrap();
        // budget = 0.5 * 30 = 15
        assert!(outcomes.iter().all(|o| o.selector.candidates <= 15));
    }

    #[test]
    fn one_round_delay_still_learns() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = tiny();
        cfg.rounds = 40;
        cfg.eval_every = 5;
        let (record, _) = run(&cfg).unwrap();
        // the one-round-delay scheme must not break learning: accuracy
        // well above chance (1/6) and clearly above the first checkpoint
        let first = record.curve.first().unwrap().test_accuracy;
        let best = record.best_accuracy();
        assert!(best > 0.4, "no learning through the pipeline: best {best}");
        assert!(best >= first, "accuracy regressed: {first} -> {best}");
    }
}
