//! Sequential coordinator — **deprecated thin shim** over the session
//! API ([`crate::coordinator::session`]).
//!
//! Selection and training alternate on one thread: how the paper's
//! baselines deploy (no pipeline), and the ablation arm of Fig. 6(a).
//! The round loop itself lives in [`crate::coordinator::session::Session`];
//! this module only pins the backend to `ExecBackend::Sequential`.

use crate::config::RunConfig;
use crate::coordinator::session::SessionBuilder;
use crate::coordinator::RoundOutcome;
use crate::metrics::RunRecord;
use crate::Result;

/// Run a full sequential training run; returns the run record and the
/// per-round outcomes.
#[deprecated(note = "use coordinator::session::SessionBuilder::new(cfg).sequential().run()")]
pub fn run(cfg: &RunConfig) -> Result<(RunRecord, Vec<RoundOutcome>)> {
    SessionBuilder::new(cfg.clone()).sequential().run()
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]

    use super::*;
    use crate::config::{presets, Method};

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny(method: Method) -> RunConfig {
        let mut c = presets::table1("mlp", method);
        c.rounds = 6;
        c.test_size = 200;
        c.eval_every = 3;
        c.pipeline = false;
        c
    }

    #[test]
    fn sequential_run_all_methods_smoke() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        for method in [Method::Rs, Method::Is, Method::Hl, Method::Ce, Method::Camel, Method::Cis] {
            let (record, outcomes) = run(&tiny(method)).unwrap();
            assert_eq!(outcomes.len(), 6, "{method:?}");
            assert_eq!(record.curve.len(), 2, "{method:?}");
            assert!(record.final_accuracy >= 0.0 && record.final_accuracy <= 1.0);
            assert!(record.total_device_ms > 0.0);
            assert!(record.energy_j > 0.0);
            assert!(outcomes.iter().all(|o| o.train_loss.is_finite()));
        }
    }

    #[test]
    fn titan_sequential_uses_filter() {
        if !have_artifacts() {
            return;
        }
        let (record, outcomes) = run(&tiny(Method::Titan)).unwrap();
        assert!(outcomes[0].selector.candidates <= 30);
        assert!(record.total_device_ms > 0.0);
        // Titan's GPU lane (filter+importance-on-30) must be cheaper than
        // IS's (importance-on-100)
        let (_, is_outcomes) = run(&tiny(Method::Is)).unwrap();
        assert!(
            outcomes[0].device_gpu_ms < is_outcomes[0].device_gpu_ms,
            "titan {} vs is {}",
            outcomes[0].device_gpu_ms,
            is_outcomes[0].device_gpu_ms
        );
    }

    #[test]
    fn deterministic_under_seed() {
        if !have_artifacts() {
            return;
        }
        let (r1, _) = run(&tiny(Method::Cis)).unwrap();
        let (r2, _) = run(&tiny(Method::Cis)).unwrap();
        assert_eq!(r1.final_accuracy, r2.final_accuracy);
        let c1: Vec<f64> = r1.curve.iter().map(|p| p.test_loss).collect();
        let c2: Vec<f64> = r2.curve.iter().map(|p| p.test_loss).collect();
        assert_eq!(c1, c2);
    }

    /// The shim must be exactly a Session with the Sequential backend.
    #[test]
    fn shim_matches_session_builder() {
        if !have_artifacts() {
            return;
        }
        let cfg = tiny(Method::Cis);
        let (shim, _) = run(&cfg).unwrap();
        let (sess, _) = SessionBuilder::new(cfg).sequential().run().unwrap();
        assert_eq!(shim.final_accuracy, sess.final_accuracy);
        let a: Vec<f64> = shim.curve.iter().map(|p| p.test_loss).collect();
        let b: Vec<f64> = sess.curve.iter().map(|p| p.test_loss).collect();
        assert_eq!(a, b);
    }
}
