//! Sequential coordinator: selection and training alternate on one
//! thread. This is how the paper's baselines deploy (no pipeline), and
//! the ablation arm of Fig. 6(a).

use crate::config::RunConfig;
use crate::coordinator::{build_stream, RoundOutcome, SelectorEngine, TrainerEngine};
use crate::device::{memory, DeviceSim, Lane, Op};
use crate::metrics::{CurvePoint, RunRecord};
use crate::util::timer::Stopwatch;
use crate::Result;

/// Run a full sequential training run; returns the run record and the
/// per-round outcomes.
pub fn run(cfg: &RunConfig) -> Result<(RunRecord, Vec<RoundOutcome>)> {
    cfg.validate()?;
    let (mut stream, test) = build_stream(cfg);
    let mut selector = SelectorEngine::new(cfg, stream.task())?;
    let mut trainer = TrainerEngine::new(cfg)?;
    let mut sim = DeviceSim::new(&cfg.model);
    let mut record = RunRecord::new(cfg.method.name(), &cfg.model);
    let mut outcomes = Vec::with_capacity(cfg.rounds);
    let run_sw = Stopwatch::start();

    for round in 0..cfg.rounds {
        // selection (uses current params — sequential has no delay);
        // share_params: refcount bump, not a param-vector clone
        selector.sync_params(trainer.share_params())?;
        let arrivals = stream.next_round(cfg.stream_per_round);
        let (batch, sel_report) = selector.select_round(round, arrivals)?;
        for &op in &sel_report.ops {
            sim.record(Lane::Gpu, op);
        }
        record
            .processing_delay
            .record_ms(sel_report.per_sample_host_ms);

        // training (weighted: the paper's unbiased estimator)
        let (loss, train_ms) = trainer.train_batch(&batch)?;
        sim.record(Lane::Cpu, Op::TrainStep { batch: batch.len() });
        let timing = sim.end_round(false); // sequential: lanes serialize

        record.round_device_ms.push(timing.wall_ms);
        record.round_host_ms.push(sel_report.host_ms + train_ms);
        outcomes.push(RoundOutcome {
            round,
            train_loss: loss,
            train_host_ms: train_ms,
            selector: sel_report,
            device_wall_ms: timing.wall_ms,
            device_cpu_ms: timing.cpu_ms,
            device_gpu_ms: timing.gpu_ms,
        });

        // periodic eval (instrumentation; not charged to the device clock)
        if cfg.eval_every > 0 && (round + 1) % cfg.eval_every == 0 {
            let rep = trainer.evaluate(&test)?;
            record.curve.push(CurvePoint {
                round: round + 1,
                device_ms: sim.total_ms(),
                host_ms: run_sw.elapsed_ms(),
                train_loss: loss as f64,
                test_loss: rep.loss,
                test_accuracy: rep.accuracy,
            });
        }
    }

    let final_eval = trainer.evaluate(&test)?;
    record.final_accuracy = final_eval.accuracy;
    record.total_device_ms = sim.total_ms();
    record.total_host_ms = run_sw.elapsed_ms();
    record.energy_j = sim.energy().energy_j();
    record.avg_power_w = sim.energy().avg_power_w();
    let meta = &trainer.rt.set.meta;
    record.peak_memory_bytes = memory::estimate(
        meta.param_count,
        memory::act_mult_for(&cfg.model),
        cfg.batch_size,
        meta.input_dim,
        cfg.candidate_size,
        meta.cand_max,
        meta.feature_dim(cfg.filter_blocks),
        meta.filter_chunk,
        false,
    )
    .total();
    Ok((record, outcomes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Method};

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn tiny(method: Method) -> RunConfig {
        let mut c = presets::table1("mlp", method);
        c.rounds = 6;
        c.test_size = 200;
        c.eval_every = 3;
        c.pipeline = false;
        c
    }

    #[test]
    fn sequential_run_all_methods_smoke() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        for method in [Method::Rs, Method::Is, Method::Hl, Method::Ce, Method::Camel, Method::Cis] {
            let (record, outcomes) = run(&tiny(method)).unwrap();
            assert_eq!(outcomes.len(), 6, "{method:?}");
            assert_eq!(record.curve.len(), 2, "{method:?}");
            assert!(record.final_accuracy >= 0.0 && record.final_accuracy <= 1.0);
            assert!(record.total_device_ms > 0.0);
            assert!(record.energy_j > 0.0);
            assert!(outcomes.iter().all(|o| o.train_loss.is_finite()));
        }
    }

    #[test]
    fn titan_sequential_uses_filter() {
        if !have_artifacts() {
            return;
        }
        let (record, outcomes) = run(&tiny(Method::Titan)).unwrap();
        assert!(outcomes[0].selector.candidates <= 30);
        assert!(record.total_device_ms > 0.0);
        // Titan's GPU lane (filter+importance-on-30) must be cheaper than
        // IS's (importance-on-100)
        let (_, is_outcomes) = run(&tiny(Method::Is)).unwrap();
        assert!(
            outcomes[0].device_gpu_ms < is_outcomes[0].device_gpu_ms,
            "titan {} vs is {}",
            outcomes[0].device_gpu_ms,
            is_outcomes[0].device_gpu_ms
        );
    }

    #[test]
    fn deterministic_under_seed() {
        if !have_artifacts() {
            return;
        }
        let (r1, _) = run(&tiny(Method::Cis)).unwrap();
        let (r2, _) = run(&tiny(Method::Cis)).unwrap();
        assert_eq!(r1.final_accuracy, r2.final_accuracy);
        let c1: Vec<f64> = r1.curve.iter().map(|p| p.test_loss).collect();
        let c2: Vec<f64> = r2.curve.iter().map(|p| p.test_loss).collect();
        assert_eq!(c1, c2);
    }
}
