//! The L3 coordinator — Titan's system layer.
//!
//! Two engines split the paper's process placement:
//!
//! - [`SelectorEngine`] (GPU lane / selector process): pulls the round's
//!   stream arrivals, runs the coarse filter + the configured selection
//!   strategy, returns the training batch for the *next* round.
//! - [`TrainerEngine`] (CPU lane / trainer process): applies SGD steps
//!   with the lr schedule, evaluates on the held-out set.
//!
//! Runs are driven by the [`session`] API: a [`SessionBuilder`] assembles
//! one [`Session`] — config, [`crate::data::DataSource`], execution
//! backend, observers — and [`Session::run`] executes the single
//! canonical round loop (device-sim recording, `RunRecord` bookkeeping,
//! eval cadence, memory estimation, param sync). The [`ExecBackend`]
//! chooses *how* the loop executes:
//!
//! - `Sequential` — both engines alternate on one thread (baselines,
//!   ablations);
//! - `Pipelined` — two OS threads with one-round-delay batch handoff and
//!   per-round parameter sync, the paper's §3.4 design.
//!
//! Sessions are **step-driven**: [`Session::step`] runs one round and
//! yields a [`session::StepEvent`], with [`Session::run`] as the trivial
//! while-step wrapper, and [`Session::step_op`] exposing the five
//! sub-round micro-ops ([`round::RoundOp`]) one at a time. The [`host`]
//! module builds on that: a [`host::Fleet`] owns N session recipes and
//! interleaves them under a pluggable [`host::SchedPolicy`] —
//! round-per-tick on one thread, op-per-tick across sharded
//! work-stealing worker threads
//! ([`host::FleetBuilder::host_threads`]) — the multi-session host
//! runtime on the path to the ROADMAP's millions-of-device-sessions
//! north star.
//!
//! [`sequential`] and [`pipeline`] remain as deprecated thin shims over
//! the session API so pre-session call sites keep compiling.

pub mod host;
pub mod pipeline;
pub mod round;
pub mod sequential;
pub mod session;
pub mod snapshot;
pub mod vault;

use std::sync::Arc;

use crate::config::{Method, RunConfig};
use crate::data::{Sample, StreamSource, SynthTask};
use crate::device::idle::IdleTrace;
use crate::device::Op;
use crate::filter::CoarseFilter;
use crate::runtime::model::{ModelRuntime, RuntimeRole};
use crate::selection::{make_strategy, SelectionContext, SelectionStrategy};
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use crate::{Error, Result};

pub use host::{
    shard_of, FaultEvent, FaultTelemetry, Fleet, FleetBuilder, FleetObserver, FleetRecord,
    SchedPolicy, SessionFactory, SessionStatus, ShardStats,
};
pub use round::{RoundOp, RoundOutcome, SelectorReport};
pub use session::{Control, ExecBackend, RoundObserver, Session, SessionBuilder, StepEvent};
pub use snapshot::SessionSnapshot;

/// A selected training batch with its unbiasedness weights (see
/// `selection::SelectedBatch` — these are the owned samples crossing the
/// pipeline channel).
///
/// The samples/weights pairing is an invariant, so the fields are private
/// and construction goes through the checked [`TrainBatch::new`].
#[derive(Clone, Debug)]
pub struct TrainBatch {
    samples: Vec<Sample>,
    weights: Vec<f32>,
}

impl TrainBatch {
    /// Checked constructor: every sample carries exactly one weight.
    pub fn new(samples: Vec<Sample>, weights: Vec<f32>) -> Result<TrainBatch> {
        if samples.len() != weights.len() {
            return Err(Error::Pipeline(format!(
                "TrainBatch: {} samples vs {} weights",
                samples.len(),
                weights.len()
            )));
        }
        Ok(TrainBatch { samples, weights })
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Selector process: coarse filter + fine selection.
pub struct SelectorEngine {
    pub rt: ModelRuntime,
    cfg: RunConfig,
    strategy: Box<dyn SelectionStrategy>,
    filter: Option<CoarseFilter>,
    /// Stream class frequencies |S_y| observed so far.
    seen_per_class: Vec<u64>,
    rng: Xoshiro256,
    /// Idle-capacity trace governing the per-round candidate budget.
    pub idle: IdleTrace,
    /// When set, each round's post-filter candidates (with their coarse
    /// scores) are kept aside for the retention plane — see
    /// [`SelectorEngine::take_scored`].
    capture_scored: bool,
    last_scored: Vec<crate::data::buffer::Candidate>,
}

impl SelectorEngine {
    pub fn new(cfg: &RunConfig, task: &SynthTask) -> Result<SelectorEngine> {
        let mut rt = ModelRuntime::load(&cfg.artifacts_dir, &cfg.model, RuntimeRole::Selector)?;
        let num_classes = task.num_classes();
        if num_classes != rt.set.meta.num_classes {
            return Err(Error::Config(format!(
                "task classes {} != artifact classes {}",
                num_classes, rt.set.meta.num_classes
            )));
        }
        let filter = if cfg.method == Method::Titan {
            // the fine stage's importance window is lowered at cand_max;
            // a larger candidate budget would silently truncate at drain
            // (changing realized-candidate records), so refuse it up front
            if cfg.candidate_size > rt.set.meta.cand_max {
                return Err(Error::Config(format!(
                    "candidate_size {} exceeds the artifact's cand_max {} — \
                     candidates past the importance window are never selectable",
                    cfg.candidate_size, rt.set.meta.cand_max
                )));
            }
            rt.ensure_features(cfg.filter_blocks)?;
            Some(CoarseFilter::new(
                num_classes,
                rt.set.meta.feature_dim(cfg.filter_blocks),
                cfg.candidate_size,
                cfg.filter_lambda,
            ))
        } else {
            None
        };
        Ok(SelectorEngine {
            rt,
            cfg: cfg.clone(),
            strategy: make_strategy(cfg.method, cfg.select_threads),
            filter,
            seen_per_class: vec![0; num_classes],
            rng: Xoshiro256::seed_from_u64(cfg.seed ^ 0x5E1E_C70A),
            idle: IdleTrace::Constant(1.0),
            capture_scored: false,
            last_scored: Vec::new(),
        })
    }

    /// Ask the engine to keep each round's scored candidate set aside so
    /// the session feed can offer it to a retaining data source. Off by
    /// default — capturing clones the round's candidates (cheap `Arc`
    /// bumps, but nonzero), so it is enabled only when the source retains.
    pub fn set_capture_scored(&mut self, on: bool) {
        self.capture_scored = on;
        if !on {
            self.last_scored = Vec::new();
        }
    }

    /// Take the last round's captured candidates (coarse-filter scores for
    /// Titan, score 0.0 for baselines whose candidate set is unscored).
    /// Empty unless [`SelectorEngine::set_capture_scored`] is on.
    pub fn take_scored(&mut self) -> Vec<crate::data::buffer::Candidate> {
        std::mem::take(&mut self.last_scored)
    }

    /// Process one round's arrivals and select the next training batch.
    /// `round` indexes the idle trace. Returns the weighted batch and the
    /// op/latency report for the device simulator + metrics.
    pub fn select_round(
        &mut self,
        round: usize,
        arrivals: Vec<Sample>,
    ) -> Result<(TrainBatch, SelectorReport)> {
        let mut report = SelectorReport::default();
        let sw = Stopwatch::start();
        for s in &arrivals {
            self.seen_per_class[s.label as usize] += 1;
        }
        let meta = self.rt.set.meta.clone();

        // ---- stage 1: candidate formation ---------------------------------
        let candidates: Vec<Sample> = if let Some(filter) = self.filter.as_mut() {
            // Titan: adapt the budget to idle capacity, then feature+score
            // every arrival in chunks (process_chunk: one batched pass per
            // feature chunk, zero per-sample allocation).
            let budget = self.idle.candidate_budget(round, self.cfg.candidate_size);
            filter.set_buffer_cap(budget);
            let chunk = meta.filter_chunk;
            let fd = meta.feature_dim(self.cfg.filter_blocks);
            let mut i = 0;
            while i < arrivals.len() {
                let end = (i + chunk).min(arrivals.len());
                let refs: Vec<&Sample> = arrivals[i..end].iter().collect();
                let (feats, valid) = self.rt.features(&refs, self.cfg.filter_blocks)?;
                report.ops.push(Op::Features {
                    chunk: valid,
                    blocks: self.cfg.filter_blocks,
                });
                // re-borrow the filter (self.rt.features above needed &mut self)
                self.filter
                    .as_mut()
                    // detlint: allow(R001) invariant: Some for the whole if-let body; re-borrow only
                    .unwrap()
                    .process_chunk(&arrivals[i..end], &feats[..valid * fd]);
                i = end;
            }
            // drain bounded by the importance window: with the
            // candidate_size <= cand_max guard above this never truncates
            // (the winners-only sort is the ring's own compaction win) —
            // it documents the selectable window if budget semantics ever
            // outgrow the guard
            // detlint: allow(R001) invariant: Some for the whole if-let body; re-borrow only
            let drained = self.filter.as_mut().unwrap().drain_top(meta.cand_max);
            report.candidates = drained.len();
            if self.capture_scored {
                // retention plane: keep the scored candidates aside (Arc
                // clones of the payloads, not copies)
                self.last_scored = drained.clone();
            }
            drained.into_iter().map(|c| c.sample).collect()
        } else {
            // baselines / bare C-IS: the whole round's stream is the
            // candidate set (capped by the artifact's N).
            let n = arrivals.len().min(meta.cand_max);
            report.candidates = n;
            if self.capture_scored {
                // baselines have no coarse score; offer at 0.0 (the
                // reservoir/balanced policies ignore scores anyway)
                self.last_scored = arrivals[..n]
                    .iter()
                    .map(|s| crate::data::buffer::Candidate {
                        sample: s.clone(),
                        score: 0.0,
                    })
                    .collect();
            }
            arrivals[..n].to_vec()
        };
        if candidates.is_empty() {
            return Err(Error::Pipeline("no candidates this round".into()));
        }

        // ---- stage 2: evidence + fine selection ---------------------------
        let refs: Vec<&Sample> = candidates.iter().collect();
        let importance = if self.cfg.method.needs_importance() {
            report.ops.push(Op::Importance { n: refs.len() });
            Some(self.rt.importance(&refs)?)
        } else {
            None
        };
        let probe = if self.cfg.method.needs_forward() {
            report.ops.push(Op::Probe { n: refs.len() });
            Some(self.rt.probe(&refs)?)
        } else {
            None
        };
        // OCS needs features for its rep/div; reuse depth-1 features.
        let (features, feature_dim) = if self.cfg.method == Method::Ocs {
            let fd = meta.feature_dim(1);
            let mut feats = Vec::with_capacity(refs.len() * fd);
            let chunk = meta.filter_chunk;
            let mut i = 0;
            while i < refs.len() {
                let end = (i + chunk).min(refs.len());
                let (f, valid) = self.rt.features(&refs[i..end], 1)?;
                report.ops.push(Op::Features { chunk: valid, blocks: 1 });
                feats.extend_from_slice(&f[..valid * fd]);
                i = end;
            }
            (Some(feats), fd)
        } else {
            (None, 0)
        };
        if self.cfg.method == Method::Camel {
            report.ops.push(Op::InputDistance { n: refs.len() });
        }

        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &self.seen_per_class,
            num_classes: meta.num_classes,
            batch: self.cfg.batch_size,
            importance: importance.as_ref(),
            probe: probe.as_ref(),
            features: features.as_deref(),
            feature_dim,
        };
        let sel = self.strategy.select(&ctx, &mut self.rng)?;
        let batch: Vec<Sample> = sel.indices.iter().map(|&i| candidates[i].clone()).collect();
        if batch.is_empty() {
            return Err(Error::Pipeline("strategy selected empty batch".into()));
        }
        report.host_ms = sw.elapsed_ms();
        report.per_sample_host_ms = report.host_ms / arrivals.len().max(1) as f64;
        report.arrivals = arrivals.len();
        Ok((TrainBatch::new(batch, sel.weights)?, report))
    }

    /// Adopt fresh parameters from the trainer (the per-round sync).
    /// Takes the trainer's shared snapshot — a refcount bump, no copy.
    pub fn sync_params(&mut self, params: Arc<Vec<f32>>) -> Result<()> {
        self.rt.set_params_shared(params)
    }

    pub fn seen_per_class(&self) -> &[u64] {
        &self.seen_per_class
    }

    /// Export the selection-side run state for a session checkpoint: the
    /// selection RNG, the stream class counts, and (Titan) the coarse
    /// filter's estimators + buffer. Strategies themselves are stateless,
    /// and the runtime's params are re-synced from the trainer before
    /// every selection, so this is the complete mutable state.
    pub fn export_state(&self) -> SelectorState {
        SelectorState {
            rng: self.rng.state(),
            seen_per_class: self.seen_per_class.clone(),
            filter: self.filter.as_ref().map(|f| f.export_state()),
            retention: None,
        }
    }

    /// Restore a state exported by [`SelectorEngine::export_state`] into
    /// a freshly built engine for the same config (checkpoint resume).
    pub fn restore_state(&mut self, st: SelectorState) -> Result<()> {
        if st.seen_per_class.len() != self.seen_per_class.len() {
            return Err(Error::Config(format!(
                "selector restore: {} classes in snapshot, engine has {}",
                st.seen_per_class.len(),
                self.seen_per_class.len()
            )));
        }
        if st.filter.is_some() != self.filter.is_some() {
            return Err(Error::Config(
                "selector restore: snapshot and engine disagree on the coarse filter".into(),
            ));
        }
        self.rng = Xoshiro256::from_state(st.rng)?;
        self.seen_per_class = st.seen_per_class;
        if let (Some(filter), Some(fs)) = (self.filter.as_mut(), st.filter) {
            filter.restore_state(fs)?;
        }
        Ok(())
    }
}

/// Exported [`SelectorEngine`] run state — the selection half of a
/// [`snapshot::SessionSnapshot`]. On the pipelined backend the selector
/// thread attaches one of these to every selected batch (when an observer
/// asked for snapshots), since the trainer thread cannot reach across to
/// export it at checkpoint time.
#[derive(Clone, Debug)]
pub struct SelectorState {
    /// Raw xoshiro256** state of the selection RNG.
    pub rng: [u64; 4],
    /// Stream class frequencies |S_y| observed so far.
    pub seen_per_class: Vec<u64>,
    /// Coarse-filter state (Titan only).
    pub filter: Option<crate::filter::FilterState>,
    /// Retention-plane state (store contents + policy RNG + telemetry) —
    /// `Some` only when the run's data source retains samples. Filled in
    /// by the session layer (the source owns the store, not the engine),
    /// so [`SelectorEngine::export_state`] leaves it `None`.
    pub retention: Option<crate::retention::RetentionState>,
}

/// Trainer process: SGD + eval + lr schedule.
pub struct TrainerEngine {
    pub rt: ModelRuntime,
    cfg: RunConfig,
    round: usize,
}

impl TrainerEngine {
    pub fn new(cfg: &RunConfig) -> Result<TrainerEngine> {
        let mut rt = ModelRuntime::load(&cfg.artifacts_dir, &cfg.model, RuntimeRole::Trainer)?;
        if cfg.batch_size != rt.set.meta.train_batch {
            // alternate lowered batch (e.g. 25 for Fig. 2b); errors if the
            // artifact set has no lowering for this size
            rt.select_train_batch(cfg.batch_size)?;
        }
        Ok(TrainerEngine {
            rt,
            cfg: cfg.clone(),
            round: 0,
        })
    }

    /// Current learning rate under the decay schedule.
    pub fn lr(&self) -> f32 {
        let decays = (self.round / self.cfg.lr_decay_every.max(1)) as i32;
        self.cfg.lr * self.cfg.lr_decay.powi(decays)
    }

    /// One SGD step on the provided batch; returns (loss, host_ms).
    pub fn train(&mut self, batch: &[Sample]) -> Result<(f32, f64)> {
        let weights = vec![1.0f32; batch.len()];
        self.train_weighted(batch, &weights)
    }

    /// One weighted SGD step (the paper's unbiased estimator).
    pub fn train_weighted(&mut self, batch: &[Sample], weights: &[f32]) -> Result<(f32, f64)> {
        if batch.len() != weights.len() {
            return Err(Error::Pipeline(format!(
                "train_weighted: {} samples vs {} weights",
                batch.len(),
                weights.len()
            )));
        }
        let sw = Stopwatch::start();
        let refs: Vec<&Sample> = batch.iter().collect();
        let loss = self.rt.train_step_weighted(&refs, weights, self.lr())?;
        self.round += 1;
        Ok((loss, sw.elapsed_ms()))
    }

    /// Convenience for TrainBatch.
    pub fn train_batch(&mut self, batch: &TrainBatch) -> Result<(f32, f64)> {
        self.train_weighted(batch.samples(), batch.weights())
    }

    pub fn evaluate(&self, test: &[Sample]) -> Result<crate::runtime::EvalReport> {
        self.rt.evaluate(test)
    }

    /// Owned copy of the current parameters (tests/analysis only — the
    /// hot paths use [`TrainerEngine::share_params`]).
    pub fn params(&self) -> Vec<f32> {
        self.rt.params().to_vec()
    }

    /// Zero-copy snapshot of the current parameters for the per-round
    /// sync (refcount bump, no `Vec` clone).
    pub fn share_params(&self) -> Arc<Vec<f32>> {
        self.rt.share_params()
    }

    pub fn round(&self) -> usize {
        self.round
    }

    /// Restore mid-run trainer state from a checkpoint: the model
    /// parameters and the round counter (which drives the lr-decay
    /// schedule — restoring params without it would silently train the
    /// tail at the wrong learning rate).
    pub fn restore(&mut self, round: usize, params: Vec<f32>) -> Result<()> {
        self.rt.import_params(params)?;
        self.round = round;
        Ok(())
    }
}

/// Build the default stream source + test set for a run config (engine-
/// level helper for analyses that bypass the session loop; sessions use
/// [`session::default_source`] and the `DataSource` seam instead).
pub fn build_stream(cfg: &RunConfig) -> (StreamSource, Vec<Sample>) {
    let stream = session::default_source(cfg);
    let test = stream.task().test_set(cfg.test_size, cfg.seed);
    (stream, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/mlp/meta.json").exists()
    }

    fn small_cfg(method: Method) -> RunConfig {
        let mut c = presets::table1("mlp", method);
        c.rounds = 3;
        c.test_size = 200;
        c.eval_every = 0;
        c
    }

    #[test]
    fn selector_roundtrip_titan() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let cfg = small_cfg(Method::Titan);
        let (mut stream, _) = build_stream(&cfg);
        let mut sel = SelectorEngine::new(&cfg, stream.task()).unwrap();
        let arrivals = stream.next_round(cfg.stream_per_round);
        let (batch, report) = sel.select_round(0, arrivals).unwrap();
        assert_eq!(batch.len(), cfg.batch_size);
        assert_eq!(report.candidates, cfg.candidate_size);
        assert_eq!(report.arrivals, cfg.stream_per_round);
        assert!(report
            .ops
            .iter()
            .any(|o| matches!(o, Op::Features { .. })));
        assert!(report
            .ops
            .iter()
            .any(|o| matches!(o, Op::Importance { n } if *n == cfg.candidate_size)));
    }

    #[test]
    fn selector_rs_uses_whole_stream() {
        if !have_artifacts() {
            return;
        }
        let cfg = small_cfg(Method::Rs);
        let (mut stream, _) = build_stream(&cfg);
        let mut sel = SelectorEngine::new(&cfg, stream.task()).unwrap();
        let (batch, report) = sel
            .select_round(0, stream.next_round(cfg.stream_per_round))
            .unwrap();
        assert_eq!(batch.len(), cfg.batch_size);
        assert_eq!(report.candidates, cfg.stream_per_round);
        assert!(report.ops.is_empty(), "RS must not touch the model: {:?}", report.ops);
    }

    #[test]
    fn trainer_reduces_loss_on_repeated_batch() {
        if !have_artifacts() {
            return;
        }
        let cfg = small_cfg(Method::Rs);
        let (mut stream, _) = build_stream(&cfg);
        let arrivals = stream.next_round(20);
        let batch: Vec<Sample> = arrivals[..10].to_vec();
        let mut tr = TrainerEngine::new(&cfg).unwrap();
        let (l0, _) = tr.train(&batch).unwrap();
        let mut last = l0;
        for _ in 0..8 {
            let (l, _) = tr.train(&batch).unwrap();
            last = l;
        }
        assert!(last < l0, "{last} !< {l0}");
    }

    #[test]
    fn lr_schedule_decays() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = small_cfg(Method::Rs);
        cfg.lr = 0.1;
        cfg.lr_decay = 0.5;
        cfg.lr_decay_every = 2;
        let mut tr = TrainerEngine::new(&cfg).unwrap();
        assert!((tr.lr() - 0.1).abs() < 1e-7);
        let (mut stream, _) = build_stream(&cfg);
        let batch: Vec<Sample> = stream.next_round(10);
        tr.train(&batch).unwrap();
        tr.train(&batch).unwrap();
        assert!((tr.lr() - 0.05).abs() < 1e-7, "{}", tr.lr());
    }

    #[test]
    fn train_batch_checks_length_invariant() {
        let s = vec![Sample::new(0, 0, vec![0.0]), Sample::new(1, 1, vec![1.0])];
        assert!(TrainBatch::new(s.clone(), vec![1.0, 1.0]).is_ok());
        assert!(TrainBatch::new(s, vec![1.0]).is_err());
        assert!(TrainBatch::new(Vec::new(), Vec::new()).unwrap().is_empty());
    }

    #[test]
    fn train_weighted_rejects_length_mismatch() {
        if !have_artifacts() {
            return;
        }
        let cfg = small_cfg(Method::Rs);
        let (mut stream, _) = build_stream(&cfg);
        let batch: Vec<Sample> = stream.next_round(10);
        let mut tr = TrainerEngine::new(&cfg).unwrap();
        assert!(tr.train_weighted(&batch, &[1.0; 4]).is_err());
        assert!(tr.train_weighted(&batch, &[1.0; 10]).is_ok());
    }

    #[test]
    fn params_sync_roundtrip() {
        if !have_artifacts() {
            return;
        }
        let cfg = small_cfg(Method::Titan);
        let (mut stream, _) = build_stream(&cfg);
        let mut sel = SelectorEngine::new(&cfg, stream.task()).unwrap();
        let mut tr = TrainerEngine::new(&cfg).unwrap();
        let batch: Vec<Sample> = stream.next_round(10);
        tr.train(&batch).unwrap();
        let p = tr.share_params();
        sel.sync_params(Arc::clone(&p)).unwrap();
        assert_eq!(sel.rt.params(), &p[..]);
    }
}
