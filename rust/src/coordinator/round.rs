//! Per-round records exchanged between the engines, the device simulator
//! and the metrics plane.

use crate::device::Op;
use crate::retention::RetentionTelemetry;

/// What the selector did in one round (fed to the device simulator's GPU
/// lane and the processing-delay metrics).
#[derive(Clone, Debug, Default)]
pub struct SelectorReport {
    /// Simulated-device operations issued on the selection lane.
    pub ops: Vec<Op>,
    /// Host wall time of the whole selection round (ms).
    pub host_ms: f64,
    /// Host per-streaming-sample processing delay (ms).
    pub per_sample_host_ms: f64,
    /// Number of stream arrivals processed.
    pub arrivals: usize,
    /// Candidate-set size after the coarse stage.
    pub candidates: usize,
    /// Cumulative retention telemetry as of this round — `Some` only when
    /// the run's data source retains samples (`--store-bytes > 0`). Set
    /// by the session feed after the round's candidates were offered to
    /// the store, so `bytes_held` reflects the post-round store.
    pub retention: Option<RetentionTelemetry>,
}

/// One completed training round, as the experiment harness sees it.
#[derive(Clone, Debug, Default)]
pub struct RoundOutcome {
    pub round: usize,
    pub train_loss: f32,
    /// Host ms spent in the trainer.
    pub train_host_ms: f64,
    /// Selector report for the round.
    pub selector: SelectorReport,
    /// Realized device wall ms for the round.
    pub device_wall_ms: f64,
    pub device_cpu_ms: f64,
    pub device_gpu_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_empty() {
        let r = RoundOutcome::default();
        assert_eq!(r.round, 0);
        assert!(r.selector.ops.is_empty());
    }
}
