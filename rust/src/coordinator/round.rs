//! Per-round records exchanged between the engines, the device simulator
//! and the metrics plane.

use crate::device::Op;
use crate::retention::RetentionTelemetry;

/// The sub-round micro-ops of the canonical session loop, in execution
/// order: feed → select → train → sync → record. A session steps through
/// them one at a time under
/// [`Session::step_op`](crate::coordinator::Session::step_op) — each of
/// the first four completions surfaces as a
/// [`StepEvent::OpCompleted`](crate::coordinator::StepEvent::OpCompleted)
/// micro-state, while completing [`RoundOp::Record`] closes the round and
/// surfaces as `StepEvent::RoundCompleted` instead. This is what lets the
/// sharded fleet host interleave sessions at op granularity: a scheduler
/// tick advances one session by one op, so a slow selection no longer
/// stalls a whole round's worth of everyone else's work behind it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundOp {
    /// Sync selector params and pull the round's stream arrivals
    /// (sequential backend; a no-op op on the pipelined backend, whose
    /// selector thread owns its own feed).
    Feed,
    /// Produce the round's training batch (two-stage selection plus the
    /// retention offer), and charge the selector ops to the GPU lane.
    Select,
    /// One weighted SGD step on the selected batch (CPU lane).
    Train,
    /// Close the device-sim round and ship fresh params back to the
    /// selector (the pipelined backend's per-round `Op::Sync`).
    Sync,
    /// Round bookkeeping: record pushes, observer fan-out, the eval
    /// cadence and the snapshot phase. Completion of this op IS the
    /// round completion.
    Record,
}

impl RoundOp {
    /// Stable display/telemetry tag.
    pub fn name(&self) -> &'static str {
        match self {
            RoundOp::Feed => "feed",
            RoundOp::Select => "select",
            RoundOp::Train => "train",
            RoundOp::Sync => "sync",
            RoundOp::Record => "record",
        }
    }
}

/// What the selector did in one round (fed to the device simulator's GPU
/// lane and the processing-delay metrics).
#[derive(Clone, Debug, Default)]
pub struct SelectorReport {
    /// Simulated-device operations issued on the selection lane.
    pub ops: Vec<Op>,
    /// Host wall time of the whole selection round (ms).
    pub host_ms: f64,
    /// Host per-streaming-sample processing delay (ms).
    pub per_sample_host_ms: f64,
    /// Number of stream arrivals processed.
    pub arrivals: usize,
    /// Candidate-set size after the coarse stage.
    pub candidates: usize,
    /// Cumulative retention telemetry as of this round — `Some` only when
    /// the run's data source retains samples (`--store-bytes > 0`). Set
    /// by the session feed after the round's candidates were offered to
    /// the store, so `bytes_held` reflects the post-round store.
    pub retention: Option<RetentionTelemetry>,
}

/// One completed training round, as the experiment harness sees it.
#[derive(Clone, Debug, Default)]
pub struct RoundOutcome {
    pub round: usize,
    pub train_loss: f32,
    /// Host ms spent in the trainer.
    pub train_host_ms: f64,
    /// Selector report for the round.
    pub selector: SelectorReport,
    /// Realized device wall ms for the round.
    pub device_wall_ms: f64,
    pub device_cpu_ms: f64,
    pub device_gpu_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_empty() {
        let r = RoundOutcome::default();
        assert_eq!(r.round, 0);
        assert!(r.selector.ops.is_empty());
    }

    #[test]
    fn round_op_tags_are_stable() {
        let ops =
            [RoundOp::Feed, RoundOp::Select, RoundOp::Train, RoundOp::Sync, RoundOp::Record];
        let names: Vec<&str> = ops.iter().map(|o| o.name()).collect();
        assert_eq!(names, ["feed", "select", "train", "sync", "record"]);
    }
}
