//! Substrate utilities built in-repo because the offline vendor set lacks
//! the usual crates (`rand`, `serde`, `clap`, `criterion`, `proptest`);
//! see DESIGN.md §Substitutions. Each submodule is small, documented and
//! unit-tested.

pub mod bench;
pub mod cli;
pub mod clock;
pub mod durable_io;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod sync;
pub mod timer;
