//! Minimal JSON parser + serializer (no serde in the offline vendor set).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` handled,
//! surrogate pairs included). Used for `artifacts/<model>/meta.json`,
//! `golden.json`, experiment configs and result emission. Numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — handy for golden-file comparisons.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing garbage at byte {} of {}",
                p.pos,
                p.bytes.len()
            )));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
            .map_err(|e| Error::Json(format!("{}: {e}", path.display())))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Json(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Json(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::Json(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Json(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(Error::Json(format!("expected object, got {other:?}"))),
        }
    }

    /// Object field lookup with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing key {key:?}")))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn f64_list(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn usize_list(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_strs(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- serialization -----------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        push_indent(out, indent + 1);
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    push_indent(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        push_indent(out, indent + 1);
                    }
                    write_str(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    push_indent(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no inf/nan; emit null like serde_json's lossy mode.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        // detlint: allow(R002) write! to a String is infallible (fmt::Write on String)
        let _ = write!(out, "{}", n as i64);
    } else {
        // detlint: allow(R002) write! to a String is infallible (fmt::Write on String)
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                // detlint: allow(R002) write! to a String is infallible (fmt::Write on String)
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let code = 0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo.wrapping_sub(0xDC00));
                                char::from_u32(code)
                                    .ok_or_else(|| Error::Json("bad surrogate".into()))?
                            } else {
                                char::from_u32(hi)
                                    .ok_or_else(|| Error::Json("bad \\u escape".into()))?
                            };
                            s.push(c);
                            continue; // hex4 advanced pos already
                        }
                        other => {
                            return Err(Error::Json(format!(
                                "bad escape {:?}",
                                other.map(|c| c as char)
                            )))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| Error::Json("invalid UTF-8".into()))?;
                    // detlint: allow(R001) invariant: rest is non-empty (peek() returned Some)
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(Error::Json("truncated \\u escape".into()));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| Error::Json("bad \\u escape".into()))?;
        let v = u32::from_str_radix(hex, 16)
            .map_err(|_| Error::Json("bad \\u escape".into()))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // detlint: allow(R001) invariant: the scanned span is ASCII digits/sign/dot/exp only
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number {text:?}: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for (txt, want) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-3.5e2", Json::Num(-350.0)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(Json::parse(txt).unwrap(), want, "{txt}");
        }
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn serialize_roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::Str("mlp".into())),
            ("dims", Json::from_f64s(&[1.0, 2.5, -3.0])),
            ("flag", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        for text in [j.to_string_pretty(), j.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn deterministic_key_order() {
        let j = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(j.to_string_compact(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn typed_accessors_errors() {
        let j = Json::parse(r#"{"n": 1.5}"#).unwrap();
        assert!(j.get("n").unwrap().as_usize().is_err());
        assert!(j.get("missing").is_err());
        assert!(j.get("n").unwrap().as_str().is_err());
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        let j = Json::Num(f64::NAN);
        assert_eq!(j.to_string_compact(), "null");
    }
}
