//! Wall-clock instrumentation: stopwatch + latency histogram. Used by the
//! coordinator's metrics plane and the micro-bench harness.

// blessed monotonic-clock seam (detlint D001 / clippy disallowed-methods):
// values from here only ever feed diff-ignored host-profiling fields
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

/// Simple stopwatch around `Instant`.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Latency recorder: keeps raw samples (experiments are small enough) and
/// summarizes to mean/percentiles.
#[derive(Clone, Debug, Default)]
pub struct LatencyRecorder {
    samples_ms: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a recorder from raw samples (checkpoint restore: the
    /// resumed run keeps appending to the pre-interruption history).
    pub fn from_samples(samples_ms: Vec<f64>) -> Self {
        Self { samples_ms }
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_ms.push(d.as_secs_f64() * 1e3);
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean_ms(&self) -> f64 {
        crate::util::stats::mean(&self.samples_ms)
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        crate::util::stats::percentile(&self.samples_ms, p)
    }

    pub fn total_ms(&self) -> f64 {
        self.samples_ms.iter().sum()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples_ms
    }

    /// "mean p50 p99" one-liner for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms",
            self.count(),
            self.mean_ms(),
            self.percentile_ms(50.0),
            self.percentile_ms(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn recorder_summary() {
        let mut r = LatencyRecorder::new();
        for ms in [1.0, 2.0, 3.0] {
            r.record_ms(ms);
        }
        assert_eq!(r.count(), 3);
        assert!((r.mean_ms() - 2.0).abs() < 1e-12);
        assert_eq!(r.percentile_ms(100.0), 3.0);
        assert_eq!(r.total_ms(), 6.0);
        assert!(r.summary().contains("n=3"));
        let restored = LatencyRecorder::from_samples(r.samples().to_vec());
        assert_eq!(restored.count(), 3);
        assert_eq!(restored.mean_ms(), r.mean_ms());
    }
}
