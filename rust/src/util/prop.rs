//! Mini property-testing helper (proptest is not in the vendor set).
//!
//! `forall(cases, gen, check)` runs `check` over `cases` randomly generated
//! inputs (seeded, deterministic). On failure it performs a bounded greedy
//! shrink using the case's `Shrink` implementation before panicking with
//! the minimal counterexample it found. This covers the way proptest is
//! used here: invariants over random vectors/weights/allocations.

use crate::util::rng::Xoshiro256;

/// Types that can propose structurally smaller variants of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, roughly ordered most-aggressive-first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for Vec<f64> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halve, drop front/back element, zero an element
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        if let Some(i) = self.iter().position(|&x| x != 0.0) {
            let mut z = self.clone();
            z[i] = 0.0;
            out.push(z);
        }
        out
    }
}

impl Shrink for Vec<usize> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        if let Some(i) = self.iter().position(|&x| x > 0) {
            let mut z = self.clone();
            z[i] /= 2;
            out.push(z);
        }
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        match self {
            0 => vec![],
            1 => vec![0],
            n => vec![n / 2, n - 1],
        }
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a single check. `Err(msg)` is a failure to be shrunk.
pub type CheckResult = Result<(), String>;

/// Run `check` on `cases` generated inputs. Panics with the (shrunk)
/// counterexample on failure. Deterministic under `seed`.
pub fn forall<T, G, C>(seed: u64, cases: usize, mut gen: G, mut check: C)
where
    T: Shrink,
    G: FnMut(&mut Xoshiro256) -> T,
    C: FnMut(&T) -> CheckResult,
{
    let mut rng = Xoshiro256::seed_from_u64(seed);
    for case_idx in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            let (min_input, min_msg) = shrink_failure(input, msg, &mut check);
            // detlint: allow(R001) panicking with the counterexample IS the prop-test API
            panic!(
                "property failed (case {case_idx}/{cases}, seed {seed}):\n  \
                 counterexample: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

/// Greedy bounded shrink: repeatedly take the first shrink candidate that
/// still fails, up to a step budget.
fn shrink_failure<T, C>(mut input: T, mut msg: String, check: &mut C) -> (T, String)
where
    T: Shrink,
    C: FnMut(&T) -> CheckResult,
{
    const MAX_STEPS: usize = 200;
    'outer: for _ in 0..MAX_STEPS {
        for cand in input.shrink() {
            if let Err(m) = check(&cand) {
                input = cand;
                msg = m;
                continue 'outer;
            }
        }
        break;
    }
    (input, msg)
}

/// Common generators.
pub mod gen {
    use super::Xoshiro256;

    /// Vec<f64> of length in [min_len, max_len], entries in [lo, hi].
    pub fn f64_vec(
        rng: &mut Xoshiro256,
        min_len: usize,
        max_len: usize,
        lo: f64,
        hi: f64,
    ) -> Vec<f64> {
        let len = min_len + rng.index(max_len - min_len + 1);
        (0..len).map(|_| lo + rng.next_f64() * (hi - lo)).collect()
    }

    /// Vec<usize> of a given length with entries in [0, max_val].
    pub fn usize_vec(rng: &mut Xoshiro256, len: usize, max_val: usize) -> Vec<usize> {
        (0..len).map(|_| rng.index(max_val + 1)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |rng| gen::f64_vec(rng, 0, 10, -1.0, 1.0),
            |v| {
                count += 1;
                if v.iter().all(|x| x.abs() <= 1.0) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        forall(
            2,
            100,
            |rng| gen::f64_vec(rng, 5, 20, 0.0, 10.0),
            |v| {
                if v.len() < 3 {
                    Ok(())
                } else {
                    Err(format!("len {} >= 3", v.len()))
                }
            },
        );
    }

    #[test]
    fn shrink_reaches_small_case() {
        // verify the shrinker actually reduces: collect the panic message
        let result = std::panic::catch_unwind(|| {
            forall(
                3,
                10,
                |rng| gen::f64_vec(rng, 6, 12, 0.0, 1.0),
                |v| {
                    if v.len() < 4 {
                        Ok(())
                    } else {
                        Err("too long".into())
                    }
                },
            );
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        // minimal failing length is 4
        assert!(msg.contains("counterexample"), "{msg}");
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t: (usize, Vec<usize>) = (4, vec![2, 2]);
        let shrinks = t.shrink();
        assert!(shrinks.iter().any(|(a, _)| *a < 4));
        assert!(shrinks.iter().any(|(_, v)| v.len() < 2 || v.iter().sum::<usize>() < 4));
    }
}
