//! Micro-benchmark harness (criterion is not in the vendor set).
//!
//! Cargo bench targets use `harness = false` and drive this module from
//! their `main()`. The harness does the criterion essentials: warmup,
//! timed iterations until a minimum measurement window, outlier-robust
//! summary (mean/p50/p99), black-box value sinking, and optional JSON
//! emission so EXPERIMENTS.md can cite machine-readable numbers.

// blessed monotonic-clock seam (detlint D001 / clippy disallowed-methods):
// bench timings never reach deterministic record fields
#![allow(clippy::disallowed_methods)]

use std::hint::black_box as bb;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// Re-export of the compiler black box for bench closures.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// One benchmark's summarized result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p99_ns", Json::Num(self.p99_ns)),
            ("min_ns", Json::Num(self.min_ns)),
        ])
    }

    fn fmt_time(ns: f64) -> String {
        if ns >= 1e9 {
            format!("{:.3} s", ns / 1e9)
        } else if ns >= 1e6 {
            format!("{:.3} ms", ns / 1e6)
        } else if ns >= 1e3 {
            format!("{:.3} µs", ns / 1e3)
        } else {
            format!("{ns:.0} ns")
        }
    }
}

/// Harness configuration. Defaults match a quick-but-stable local run and
/// can be tightened via env (`TITAN_BENCH_FAST=1` for smoke runs).
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if std::env::var("TITAN_BENCH_FAST").is_ok() {
            Self {
                warmup: Duration::from_millis(50),
                measure: Duration::from_millis(200),
                min_iters: 3,
                max_iters: 1_000_000,
            }
        } else {
            Self {
                warmup: Duration::from_millis(300),
                measure: Duration::from_secs(2),
                min_iters: 10,
                max_iters: 10_000_000,
            }
        }
    }
}

/// Bench session: run named closures, collect results, print a table.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
    group: String,
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        println!("== bench group: {group} ==");
        Self {
            config: BenchConfig::default(),
            results: Vec::new(),
            group: group.to_string(),
        }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Measure `f` (called once per iteration; return value is black-boxed).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup
        let wstart = Instant::now();
        while wstart.elapsed() < self.config.warmup {
            bb(f());
        }
        // Measure: per-iteration timestamps; batch tiny closures.
        let mut samples_ns: Vec<f64> = Vec::new();
        let mut iters: u64 = 0;
        let start = Instant::now();
        while (start.elapsed() < self.config.measure || iters < self.config.min_iters)
            && iters < self.config.max_iters
        {
            let t = Instant::now();
            bb(f());
            samples_ns.push(t.elapsed().as_nanos() as f64);
            iters += 1;
        }
        let mean = stats::mean(&samples_ns);
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p99_ns: stats::percentile(&samples_ns, 99.0),
            min_ns: stats::fold_min(samples_ns.iter().copied(), f64::INFINITY),
        };
        println!(
            "{:<44} {:>12}/iter  p50 {:>12}  p99 {:>12}  ({} iters)",
            result.name,
            BenchResult::fmt_time(result.mean_ns),
            BenchResult::fmt_time(result.p50_ns),
            BenchResult::fmt_time(result.p99_ns),
            result.iters
        );
        self.results.push(result);
        // detlint: allow(R001) invariant: results.push(result) on the previous line
        self.results.last().unwrap()
    }

    /// Write all results as JSON under `results/bench_<group>.json`.
    pub fn finish(self) {
        // detlint: allow(R002) best-effort mkdir; the write below reports its own failure
        let _ = std::fs::create_dir_all("results");
        let arr = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        let path = format!("results/bench_{}.json", self.group);
        if crate::util::durable_io::write_plain(Path::new(&path), arr.to_string_pretty().as_bytes())
            .is_ok()
        {
            println!("-- results written to {path}");
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new("selftest").with_config(BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 100_000,
        });
        let r = b.bench("sum", || (0..1000u64).sum::<u64>()).clone();
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns * 1.0001);
        assert!(r.min_ns <= r.mean_ns);
    }

    #[test]
    fn result_json_shape() {
        let r = BenchResult {
            name: "x".into(),
            iters: 5,
            mean_ns: 10.0,
            p50_ns: 9.0,
            p99_ns: 20.0,
            min_ns: 8.0,
        };
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("iters").unwrap().as_usize().unwrap(), 5);
    }

    #[test]
    fn fmt_time_units() {
        assert!(BenchResult::fmt_time(5.0).ends_with("ns"));
        assert!(BenchResult::fmt_time(5_000.0).ends_with("µs"));
        assert!(BenchResult::fmt_time(5_000_000.0).ends_with("ms"));
        assert!(BenchResult::fmt_time(5e9).ends_with(" s"));
    }
}
