//! Fixed-width (8-lane) striped reduction kernels on stable Rust.
//!
//! The filter's per-sample cost after PR 1 is exactly one `⟨f, c⟩` dot
//! product plus one `‖f‖²` (everything else is cached), and `VecMean`'s
//! push refreshes a cached `‖mean‖²` on every estimator update. All three
//! are straight-line f64 reductions over f32 slices, which the scalar
//! `a.iter().map(..).sum()` form chains into one serial dependency per
//! element — the compiler cannot re-associate float adds, so the loop runs
//! at the latency of one `addsd` per element instead of the machine's
//! vector width.
//!
//! These kernels stripe the accumulation across [`LANES`] = 8 independent
//! f64 accumulators (`chunks_exact(8)` body + a sequential remainder
//! tail) and fold the lanes in one fixed order ([`fold`]). That breaks the
//! dependency chain — the body auto-vectorizes / pipelines on any target —
//! while staying **fully deterministic and CPU-independent**: the lane
//! count is a compile-time constant (no `std::simd`, no runtime feature
//! detection), every term goes to a fixed lane decided only by its index,
//! and the fold order never varies. The same inputs produce bit-identical
//! outputs on every machine, which is what the resume / cross-backend
//! byte-identity pins require.
//!
//! The striped sum is a *different* float result than the scalar
//! left-to-right sum (float addition is not associative), so the scalar
//! helpers in [`crate::util::stats`] survive as the reference oracles and
//! the property tests pin wide-vs-scalar agreement at 1e-12 relative.
//! What *is* bit-pinned: [`mean_update`] leaves the cached norm exactly
//! equal to a from-scratch [`norm2`] over the updated cast (same striping,
//! same fold), so `VecMean`'s cache and its restore path stay coherent.

/// Accumulator lanes per kernel. 8 f64 lanes = one AVX-512 register or
/// two AVX2 registers — wide enough to hide FP-add latency everywhere
/// without making the remainder tail dominate at small dims.
pub const LANES: usize = 8;

/// Fold the 8 lane accumulators and the remainder tail in one fixed
/// order: pairwise tree over the lanes, then the tail last. Every kernel
/// in this module funnels through this, so "the" wide sum is well defined.
#[inline]
fn fold(lanes: [f64; LANES], tail: f64) -> f64 {
    (((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7])))
        + tail
}

/// Striped dot product of two f32 slices with f64 lane accumulation.
/// Deterministic: term `i` of the full chunks goes to lane `i % 8`; the
/// remainder accumulates sequentially into the tail; [`fold`] order fixed.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for j in 0..LANES {
            lanes[j] += xa[j] as f64 * xb[j] as f64;
        }
    }
    let mut tail = 0.0f64;
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x as f64 * y as f64;
    }
    fold(lanes, tail)
}

/// Striped squared L2 norm of an f32 slice (f64 lane accumulation), with
/// the same term-to-lane assignment and fold order as [`dot`].
pub fn norm2(a: &[f32]) -> f64 {
    let mut lanes = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    for xa in &mut ca {
        for j in 0..LANES {
            lanes[j] += xa[j] as f64 * xa[j] as f64;
        }
    }
    let mut tail = 0.0f64;
    for &x in ca.remainder() {
        tail += x as f64 * x as f64;
    }
    fold(lanes, tail)
}

/// Fused wide `VecMean` update: for every element, advance the f64
/// running mean by `(x - mean) * inv`, refresh its f32 cast, and
/// accumulate the cast's square — returning the new `‖cast‖²`.
///
/// The square accumulation uses the **exact** striping of [`norm2`]
/// (full chunks stripe by `i % 8`, remainder goes to the tail, same
/// [`fold`]), so the returned value is bit-identical to calling
/// `norm2(cast)` after the update. `VecMean::from_state` re-derives its
/// cache through `norm2`, which is what makes a restored accumulator
/// bit-identical to a live one.
///
/// The per-element mean/cast updates are element-local (no cross-element
/// accumulation), so their results are independent of the chunking.
pub fn mean_update(mean: &mut [f64], cast: &mut [f32], x: &[f32], inv: f64) -> f64 {
    debug_assert_eq!(mean.len(), cast.len());
    debug_assert_eq!(mean.len(), x.len());
    let mut lanes = [0.0f64; LANES];
    let mut cm = mean.chunks_exact_mut(LANES);
    let mut cc = cast.chunks_exact_mut(LANES);
    let mut cx = x.chunks_exact(LANES);
    for ((m, c), v) in (&mut cm).zip(&mut cc).zip(&mut cx) {
        for j in 0..LANES {
            m[j] += (v[j] as f64 - m[j]) * inv;
            c[j] = m[j] as f32;
            lanes[j] += c[j] as f64 * c[j] as f64;
        }
    }
    let mut tail = 0.0f64;
    for ((m, c), &v) in cm
        .into_remainder()
        .iter_mut()
        .zip(cc.into_remainder().iter_mut())
        .zip(cx.remainder())
    {
        *m += (v as f64 - *m) * inv;
        *c = *m as f32;
        tail += *c as f64 * *c as f64;
    }
    fold(lanes, tail)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    use crate::util::stats;

    /// Dims that exercise every remainder-lane shape: empty, sub-width,
    /// exact widths, one-over, and multi-chunk one-under/over.
    const DIMS: [usize; 9] = [0, 1, 7, 8, 9, 16, 63, 64, 65];

    fn rand_f32s(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.next_f64() * 4.0 - 2.0) as f32).collect()
    }

    #[test]
    fn wide_matches_scalar_reference_at_every_remainder_shape() {
        let mut rng = Xoshiro256::seed_from_u64(0x51D0);
        for &dim in &DIMS {
            for _ in 0..20 {
                let a = rand_f32s(&mut rng, dim);
                let b = rand_f32s(&mut rng, dim);
                let (wd, sd) = (dot(&a, &b), stats::dot(&a, &b));
                assert!(
                    (wd - sd).abs() <= 1e-12 * sd.abs().max(1.0),
                    "dot dim {dim}: wide {wd} vs scalar {sd}"
                );
                let (wn, sn) = (norm2(&a), stats::norm2(&a));
                assert!(
                    (wn - sn).abs() <= 1e-12 * sn.abs().max(1.0),
                    "norm2 dim {dim}: wide {wn} vs scalar {sn}"
                );
            }
        }
    }

    #[test]
    fn wide_kernels_are_deterministic() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for &dim in &DIMS {
            let a = rand_f32s(&mut rng, dim);
            let b = rand_f32s(&mut rng, dim);
            assert_eq!(dot(&a, &b).to_bits(), dot(&a, &b).to_bits());
            assert_eq!(norm2(&a).to_bits(), norm2(&a).to_bits());
        }
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(norm2(&[]), 0.0);
        assert_eq!(mean_update(&mut [], &mut [], &[], 1.0), 0.0);
    }

    #[test]
    fn mean_update_cache_equals_from_scratch_norm2_bitwise() {
        // THE coherence pin: the fused update's returned norm must equal
        // norm2() over the updated cast EXACTLY — VecMean's cached value
        // and its restore path both depend on it.
        let mut rng = Xoshiro256::seed_from_u64(0xCAFE);
        for &dim in &DIMS {
            let mut mean = vec![0.0f64; dim];
            let mut cast = vec![0.0f32; dim];
            for step in 1..=50u64 {
                let x = rand_f32s(&mut rng, dim);
                let got = mean_update(&mut mean, &mut cast, &x, 1.0 / step as f64);
                assert_eq!(
                    got.to_bits(),
                    norm2(&cast).to_bits(),
                    "dim {dim} step {step}: fused {got} != from-scratch {}",
                    norm2(&cast)
                );
            }
        }
    }

    #[test]
    fn mean_update_mean_matches_elementwise_reference() {
        // chunking must not change the per-element mean math
        let mut rng = Xoshiro256::seed_from_u64(0xBEEF);
        for &dim in &DIMS {
            let mut mean = vec![0.0f64; dim];
            let mut cast = vec![0.0f32; dim];
            let mut ref_mean = vec![0.0f64; dim];
            for step in 1..=20u64 {
                let x = rand_f32s(&mut rng, dim);
                let inv = 1.0 / step as f64;
                mean_update(&mut mean, &mut cast, &x, inv);
                for (m, &v) in ref_mean.iter_mut().zip(&x) {
                    *m += (v as f64 - *m) * inv;
                }
                assert_eq!(mean, ref_mean, "dim {dim} step {step}");
                let want: Vec<f32> = ref_mean.iter().map(|&m| m as f32).collect();
                assert_eq!(cast, want, "dim {dim} step {step}");
            }
        }
    }

    #[test]
    fn known_values() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(norm2(&a), 14.0);
        // a width-straddling exact case: 10 ones
        let ones = [1.0f32; 10];
        assert_eq!(norm2(&ones), 10.0);
        assert_eq!(dot(&ones, &ones), 10.0);
    }
}
