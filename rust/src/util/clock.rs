//! The single wall-clock seam.
//!
//! Every wall-clock read in the library goes through this module (or
//! through `util::timer` / `util::bench`, the monotonic profiling
//! seams), so `scripts/detlint.py` rule D001 can bless exactly three
//! files and flag any other `Instant::now` / `SystemTime::now` as a
//! determinism hazard.
//!
//! Audit of where clock values are allowed to flow (none of these reach
//! deterministic record fields):
//!
//! - `util::logging` stamps stderr lines with [`unix_now`]; log output
//!   is never diffed or snapshotted.
//! - `runtime::cache` keys compiled executables by [`file_mtime`]; the
//!   key only controls cache hits, never computed values.
//! - `util::timer` / `util::bench` feed host-profiling fields
//!   (`*_ms`), which the record differ ignores by contract
//!   (see DETERMINISM.md).
//!
//! `FleetRecord`, snapshots, and telemetry JSON must stay clock-free;
//! detlint enforces the module boundary, this doc records the intent.

#![allow(clippy::disallowed_methods)] // this IS the blessed clock seam

use std::path::Path;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// Seconds-precision wall clock for log stamps. Returns the duration
/// since the Unix epoch, or zero if the system clock is before it.
pub fn unix_now() -> Duration {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default()
}

/// Modification time of `path`, for cache keying only. Filesystems
/// without mtime support report the Unix epoch (a stable degenerate
/// key: the cache then revalidates on every compile, never misserves).
pub fn file_mtime(path: &Path) -> std::io::Result<SystemTime> {
    Ok(std::fs::metadata(path)?.modified().unwrap_or(SystemTime::UNIX_EPOCH))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unix_now_is_post_2020() {
        // 2020-01-01T00:00:00Z — sanity-checks the epoch basis.
        assert!(unix_now().as_secs() > 1_577_836_800);
    }

    #[test]
    fn mtime_of_missing_file_errors() {
        assert!(file_mtime(Path::new("definitely/not/a/file.hlo")).is_err());
    }

    #[test]
    fn mtime_of_real_file_succeeds() {
        assert!(file_mtime(Path::new("Cargo.toml")).is_ok());
    }
}
