//! Minimal `log` facade backend (env_logger is not in the vendor set).
//!
//! Level comes from `TITAN_LOG` (error|warn|info|debug|trace), default
//! `info`. Output: `[HH:MM:SS.mmm LEVEL target] message` on stderr.

use std::io::Write;
use std::sync::Once;

use log::{Level, LevelFilter, Log, Metadata, Record};

use crate::util::clock;

struct StderrLogger {
    max: Level,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        // Wall clock only stamps stderr; records never see it (see util::clock).
        let now = clock::unix_now();
        let secs = now.as_secs();
        let ms = now.subsec_millis();
        let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
        // detlint: allow(R002) a logger cannot log its own write failure; dropping is the only option
        let _ = writeln!(
            std::io::stderr(),
            "[{h:02}:{m:02}:{s:02}.{ms:03} {:5} {}] {}",
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger once; safe to call repeatedly.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("TITAN_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        let filter = level.to_level_filter();
        // Leak a single logger for the process lifetime (standard pattern).
        let logger: &'static StderrLogger = Box::leak(Box::new(StderrLogger { max: level }));
        if log::set_logger(logger).is_ok() {
            log::set_max_level(filter);
        }
    });
}

/// Set max level programmatically (tests / quiet benches).
pub fn set_level(filter: LevelFilter) {
    init();
    log::set_max_level(filter);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_idempotent() {
        init();
        init();
        log::info!("logging smoke");
        set_level(LevelFilter::Error);
        set_level(LevelFilter::Info);
    }
}
