//! Minimal cross-thread handoff primitives for the pipeline coordinator
//! (the vendor set has no `crossbeam`/`arc-swap`; std only).

use std::sync::Mutex;

/// A latest-only slot: a capacity-1 cell with **overwrite** semantics.
///
/// `publish` replaces any unconsumed value; `take` removes the freshest
/// one. Both are non-blocking, so a producer can keep publishing while the
/// consumer lags and memory stays bounded at one value — exactly the
/// parameter-sync contract of the §3.4 pipeline (the selector only ever
/// wants the *newest* weights; stale intermediates are worthless).
///
/// Contrast with the two alternatives it replaced:
/// - `mpsc::channel` (unbounded): a lagging consumer queues every stale
///   snapshot — memory grows with the lag.
/// - `mpsc::sync_channel(1)` (bounded, blocking): the producer stalls on a
///   full slot — the trainer would wait on the selector, defeating the
///   lane overlap.
#[derive(Debug)]
pub struct Latest<T> {
    slot: Mutex<Option<T>>,
}

impl<T> Default for Latest<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Latest<T> {
    pub fn new() -> Self {
        Self {
            slot: Mutex::new(None),
        }
    }

    /// Publish a value, overwriting any unconsumed one. Returns `true` if
    /// an unconsumed value was dropped (the consumer is lagging).
    pub fn publish(&self, value: T) -> bool {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        slot.replace(value).is_some()
    }

    /// Take the latest value, leaving the slot empty. Non-blocking.
    pub fn take(&self) -> Option<T> {
        self.slot.lock().unwrap_or_else(|e| e.into_inner()).take()
    }

    /// Whether a value is currently waiting.
    pub fn is_empty(&self) -> bool {
        self.slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn publish_take_roundtrip() {
        let s: Latest<u32> = Latest::new();
        assert!(s.is_empty());
        assert!(s.take().is_none());
        assert!(!s.publish(1));
        assert!(!s.is_empty());
        assert_eq!(s.take(), Some(1));
        assert!(s.take().is_none());
    }

    #[test]
    fn overwrite_keeps_only_latest() {
        let s: Latest<u32> = Latest::new();
        assert!(!s.publish(1));
        assert!(s.publish(2), "must report the dropped stale value");
        assert!(s.publish(3));
        assert_eq!(s.take(), Some(3));
    }

    #[test]
    fn bounded_under_producer_burst() {
        // a lagging consumer must see exactly one (the newest) value no
        // matter how many were published — the unbounded-channel regression
        let s: Latest<Arc<Vec<f32>>> = Latest::new();
        for i in 0..1000 {
            s.publish(Arc::new(vec![i as f32]));
        }
        assert_eq!(s.take().unwrap()[0], 999.0);
        assert!(s.take().is_none());
    }

    #[test]
    fn cross_thread_handoff() {
        let s = Arc::new(Latest::<u64>::new());
        let p = Arc::clone(&s);
        let h = std::thread::spawn(move || {
            for i in 0..100u64 {
                p.publish(i);
            }
        });
        h.join().unwrap();
        assert_eq!(s.take(), Some(99));
    }
}
