//! Minimal cross-thread handoff primitives for the pipeline coordinator
//! (the vendor set has no `crossbeam`/`arc-swap`; std only).

use std::sync::Mutex;

/// A latest-only slot: a capacity-1 cell with **overwrite** semantics.
///
/// `publish` replaces any unconsumed value; `take` removes the freshest
/// one. Both are non-blocking, so a producer can keep publishing while the
/// consumer lags and memory stays bounded at one value — exactly the
/// parameter-sync contract of the §3.4 pipeline (the selector only ever
/// wants the *newest* weights; stale intermediates are worthless).
///
/// Contrast with the two alternatives it replaced:
/// - `mpsc::channel` (unbounded): a lagging consumer queues every stale
///   snapshot — memory grows with the lag.
/// - `mpsc::sync_channel(1)` (bounded, blocking): the producer stalls on a
///   full slot — the trainer would wait on the selector, defeating the
///   lane overlap.
#[derive(Debug)]
pub struct Latest<T> {
    slot: Mutex<Option<T>>,
}

impl<T> Default for Latest<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Latest<T> {
    pub fn new() -> Self {
        Self {
            slot: Mutex::new(None),
        }
    }

    /// Publish a value, overwriting any unconsumed one. Returns `true` if
    /// an unconsumed value was dropped (the consumer is lagging).
    pub fn publish(&self, value: T) -> bool {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        slot.replace(value).is_some()
    }

    /// Take the latest value, leaving the slot empty. Non-blocking.
    pub fn take(&self) -> Option<T> {
        self.slot.lock().unwrap_or_else(|e| e.into_inner()).take()
    }

    /// Whether a value is currently waiting.
    pub fn is_empty(&self) -> bool {
        self.slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .is_none()
    }
}

#[cfg(test)]
// contention tests need raw OS threads; test threads never touch records
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn publish_take_roundtrip() {
        let s: Latest<u32> = Latest::new();
        assert!(s.is_empty());
        assert!(s.take().is_none());
        assert!(!s.publish(1));
        assert!(!s.is_empty());
        assert_eq!(s.take(), Some(1));
        assert!(s.take().is_none());
    }

    #[test]
    fn overwrite_keeps_only_latest() {
        let s: Latest<u32> = Latest::new();
        assert!(!s.publish(1));
        assert!(s.publish(2), "must report the dropped stale value");
        assert!(s.publish(3));
        assert_eq!(s.take(), Some(3));
    }

    #[test]
    fn bounded_under_producer_burst() {
        // a lagging consumer must see exactly one (the newest) value no
        // matter how many were published — the unbounded-channel regression
        let s: Latest<Arc<Vec<f32>>> = Latest::new();
        for i in 0..1000 {
            s.publish(Arc::new(vec![i as f32]));
        }
        assert_eq!(s.take().unwrap()[0], 999.0);
        assert!(s.take().is_none());
    }

    #[test]
    fn cross_thread_handoff() {
        let s = Arc::new(Latest::<u64>::new());
        let p = Arc::clone(&s);
        let h = std::thread::spawn(move || {
            for i in 0..100u64 {
                p.publish(i);
            }
        });
        h.join().unwrap();
        assert_eq!(s.take(), Some(99));
    }

    /// Overwrite under contention: several producers race a consumer.
    /// Every observed value must be one somebody published, values from a
    /// single producer must be observed in publish order (a later take
    /// never yields an older value from the same producer), and once all
    /// producers finish, the slot holds exactly one final value.
    #[test]
    fn overwrite_under_contention() {
        const PRODUCERS: u64 = 4;
        const PER_PRODUCER: u64 = 500;
        let s = Arc::new(Latest::<(u64, u64)>::new()); // (producer, seq)
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let slot = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        slot.publish((p, i));
                    }
                })
            })
            .collect();
        // consume concurrently, tracking the last seq seen per producer
        let mut last_seq = [None::<u64>; PRODUCERS as usize];
        let mut observed = 0usize;
        while handles.iter().any(|h| !h.is_finished()) {
            if let Some((p, i)) = s.take() {
                assert!(p < PRODUCERS && i < PER_PRODUCER, "({p},{i})");
                if let Some(prev) = last_seq[p as usize] {
                    assert!(i > prev, "producer {p} went backwards: {prev} -> {i}");
                }
                last_seq[p as usize] = Some(i);
                observed += 1;
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        // after the burst: at most the single freshest value remains
        let final_v = s.take();
        assert!(final_v.is_some() || observed > 0, "nothing ever observed");
        assert!(s.take().is_none(), "slot must hold at most one value");
        assert!(s.is_empty());
    }

    /// The slot is storage, not a channel: a value published by a sender
    /// that has since dropped (its thread gone, its Arc released) is still
    /// takeable.
    #[test]
    fn take_after_sender_drop() {
        let s = Arc::new(Latest::<Vec<u32>>::new());
        {
            let p = Arc::clone(&s);
            std::thread::spawn(move || {
                p.publish(vec![1, 2, 3]);
                // p dropped here: the producer's handle on the slot is gone
            })
            .join()
            .unwrap();
        }
        assert_eq!(Arc::strong_count(&s), 1, "sender fully dropped");
        assert_eq!(s.take(), Some(vec![1, 2, 3]));
        assert!(s.take().is_none());
    }
}
