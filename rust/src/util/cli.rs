//! Tiny CLI argument parser (clap is not in the vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Typed getters with defaults keep call sites terse; `usage()` renders a
//! help string from the declared options.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Declared option, for help rendering.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates option parsing
                    out.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // value-style if next token exists and isn't an option
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            // detlint: allow(R001) invariant: peek() just returned Some
                            let v = iter.next().unwrap();
                            out.options.insert(rest.to_string(), v);
                        }
                        _ => out.flags.push(rest.to_string()),
                    }
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process command line.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Declare an option for `usage()`.
    pub fn declare(&mut self, name: &'static str, help: &'static str, default: Option<&'static str>) {
        self.specs.push(OptSpec { name, help, default });
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Config(format!("--{name}={v}: {e}"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Config(format!("--{name}={v}: {e}"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Config(format!("--{name}={v}: {e}"))),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        Ok(self.get_f64(name, default as f64)? as f32)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string())
                .collect(),
        }
    }

    /// Render declared options as a help block.
    pub fn usage(&self) -> String {
        let mut s = String::from("options:\n");
        for spec in &self.specs {
            s.push_str(&format!("  --{:<18} {}", spec.name, spec.help));
            if let Some(d) = spec.default {
                s.push_str(&format!(" [default: {d}]"));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_options() {
        // note: a bare `--opt value` pair is value-style by design, so
        // boolean flags must come last or use `--` before positionals
        let a = parse(&["run", "extra", "--model", "mlp", "--rounds=20", "--fast"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 20);
        assert!(a.has_flag("fast"));
        assert!(!a.has_flag("slow"));
    }

    #[test]
    fn flag_followed_by_positional_binds_as_value() {
        // documents the ambiguity resolution: `--fast extra` parses as
        // --fast=extra (value-style wins when the next token is bare)
        let a = parse(&["--fast", "extra"]);
        assert_eq!(a.get("fast"), Some("extra"));
        assert!(!a.has_flag("fast"));
    }

    #[test]
    fn defaults_and_types() {
        let a = parse(&["--lr", "0.05"]);
        assert_eq!(a.get_f64("lr", 0.1).unwrap(), 0.05);
        assert_eq!(a.get_f64("other", 0.1).unwrap(), 0.1);
        assert_eq!(a.get_str("name", "d"), "d");
        assert!(parse(&["--n", "abc"]).get_usize("n", 1).is_err());
    }

    #[test]
    fn list_option() {
        let a = parse(&["--models", "mlp,squeeze"]);
        assert_eq!(a.get_list("models", &["x"]), vec!["mlp", "squeeze"]);
        assert_eq!(a.get_list("absent", &["x"]), vec!["x"]);
    }

    #[test]
    fn double_dash_terminates() {
        let a = parse(&["--x", "1", "--", "--not-an-option"]);
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn usage_renders() {
        let mut a = parse(&[]);
        a.declare("model", "model variant", Some("mlp"));
        let u = a.usage();
        assert!(u.contains("--model"));
        assert!(u.contains("default: mlp"));
    }
}
