//! The blessed durable-write seam: every byte this crate persists goes
//! through here (detlint rule R003 flags raw `std::fs::write` /
//! `File::create` anywhere else under `rust/src/`).
//!
//! Two write disciplines:
//!
//! - [`write_atomic`]: unique temp file + rename, for state a crash must
//!   never destroy (checkpoints, vault generations). An interruption
//!   mid-write leaves the previous file intact; concurrent writers to
//!   the same destination cannot rename each other's half-written temp
//!   into place because every temp name is unique per call and process.
//!   With `TITAN_FSYNC=1` the temp file (and, on Unix, its directory)
//!   is fsynced before/after the rename so the bytes survive power
//!   loss, not just process death — see PERF.md for the cost.
//! - [`write_plain`] / [`create_file`]: ordinary writes for replaceable
//!   outputs (result JSON, CSV exports, bench reports) that are cheap
//!   to regenerate and never resumed from.
//!
//! [`sweep_stale_tmp`] reclaims temp files a kill orphaned between
//! write and rename: temp names are unique per incarnation, so nothing
//! else would ever collect them across crash/resume cycles.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Distinguishes concurrent writers within one process; the pid in the
/// temp name handles concurrent processes.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Whether durable writes fsync (`TITAN_FSYNC=1`); read once per
/// process so the hot snapshot path never touches the environment.
pub fn fsync_enabled() -> bool {
    static FSYNC: OnceLock<bool> = OnceLock::new();
    *FSYNC.get_or_init(|| {
        std::env::var("TITAN_FSYNC").map(|v| v == "1").unwrap_or(false)
    })
}

/// `<path>.<pid>.<seq>.tmp` — unique per call and process, so writers
/// sharing a destination stem can never race on one temp file.
pub fn unique_tmp(path: &Path) -> PathBuf {
    let mut name = path.as_os_str().to_owned();
    name.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    PathBuf::from(name)
}

/// Remove `<file_name>.*.tmp` siblings left by earlier incarnations.
/// Best-effort: a survivor is re-swept at the next start.
pub fn sweep_stale_tmp(path: &Path) {
    let (Some(dir), Some(stem)) = (path.parent(), path.file_name()) else {
        return;
    };
    let Some(stem) = stem.to_str() else { return };
    let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if name.len() > stem.len() + 1
            && name.starts_with(stem)
            && name.as_bytes()[stem.len()] == b'.'
            && name.ends_with(".tmp")
        {
            // detlint: allow(R002) best-effort orphan sweep; a survivor is re-swept next start
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

/// Atomic replace: write `bytes` to a unique temp sibling, optionally
/// fsync it, and rename it over `path`. On any failure the temp file is
/// removed and the previous `path` contents are untouched.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = unique_tmp(path);
    let result = write_and_rename(&tmp, path, bytes);
    if result.is_err() {
        // detlint: allow(R002) best-effort temp cleanup after a reported failure
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

fn write_and_rename(tmp: &Path, path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    {
        let mut f = File::create(tmp)?;
        f.write_all(bytes)?;
        if fsync_enabled() {
            f.sync_all()?;
        }
    }
    std::fs::rename(tmp, path)?;
    if fsync_enabled() {
        // persist the rename itself: fsync the containing directory
        // (no-op on platforms where directories cannot be opened)
        if let Some(dir) = path.parent() {
            let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
            if let Ok(d) = File::open(dir) {
                // detlint: allow(R002) some filesystems refuse directory fsync; data fsync already ran
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// Plain (non-atomic) write for regenerable outputs — results, CSVs,
/// bench reports. Not for anything a resume path reads back.
pub fn write_plain(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    std::fs::write(path, bytes)
}

/// Blessed `File::create` for streaming writers (CSV export). Same
/// caveat as [`write_plain`]: replaceable outputs only.
pub fn create_file(path: &Path) -> std::io::Result<File> {
    File::create(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temp_names_are_unique_per_call() {
        let p = Path::new("/tmp/titan_durable_io.json");
        assert_ne!(unique_tmp(p), unique_tmp(p));
        assert!(unique_tmp(p).to_str().unwrap().ends_with(".tmp"));
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("titan_durable_io_atomic");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        write_atomic(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        write_atomic(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_str().unwrap().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files survived: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_tmp_sweep_reclaims_orphans() {
        let dir = std::env::temp_dir().join("titan_durable_io_sweep");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        let orphan = dir.join("ck.json.1234.9.tmp");
        std::fs::write(&orphan, b"half").unwrap();
        let unrelated = dir.join("other.json.1.0.tmp");
        std::fs::write(&unrelated, b"keep").unwrap();
        sweep_stale_tmp(&path);
        assert!(!orphan.exists(), "orphan not swept");
        assert!(unrelated.exists(), "sweep must only touch its own stem");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
