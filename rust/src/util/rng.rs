//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so we implement the two
//! standard small generators used across the codebase:
//!
//! - [`SplitMix64`] — seeding / stream splitting (Steele et al., 2014).
//! - [`Xoshiro256`] — xoshiro256** by Blackman & Vigna, the workhorse for
//!   every stochastic component (stream generation, sampling, noise).
//!
//! On top of the raw generators we provide the distributions Titan needs:
//! uniform, standard normal (Box–Muller), categorical, weighted sampling
//! with and without replacement, shuffling, and multinomial allocation.
//! Every experiment is seeded, so all paper figures regenerate bit-for-bit.

/// SplitMix64: tiny, full-period, used to expand a single u64 seed into the
/// 256-bit xoshiro state (the construction its authors recommend).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, 2^256-1 period, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child generator (for per-thread / per-device
    /// streams). Equivalent to seeding from a fresh draw.
    pub fn split(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }

    /// Export the raw 256-bit generator state (checkpoint/resume: a
    /// restored generator continues the exact stream it was exported
    /// from, draw for draw).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from an exported [`Xoshiro256::state`].
    /// Rejects the all-zero state, which is a fixed point of the
    /// transition (the generator would emit zeros forever).
    pub fn from_state(s: [u64; 4]) -> crate::Result<Self> {
        if s == [0, 0, 0, 0] {
            return Err(crate::Error::Config(
                "xoshiro256** state must not be all-zero".into(),
            ));
        }
        Ok(Self { s })
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift with rejection
    /// (unbiased).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; we don't cache
    /// the pair — simplicity over the last 2x).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std as f32 (the data plane is f32).
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.next_normal() as f32
    }

    /// Draw an index from an unnormalized non-negative weight vector.
    /// Falls back to uniform if the total mass is zero.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        debug_assert!(!weights.is_empty());
        let total: f64 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return self.index(weights.len());
        }
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Weighted sampling WITHOUT replacement (k distinct indices,
    /// P(first pick = i) ∝ w_i), via the Efraimidis–Spirakis exponential
    /// keys method: k largest of u_i^(1/w_i).
    pub fn weighted_sample_without_replacement(
        &mut self,
        weights: &[f64],
        k: usize,
    ) -> Vec<usize> {
        let n = weights.len();
        let k = k.min(n);
        if k == 0 {
            return Vec::new();
        }
        let mut keys: Vec<(f64, usize)> = weights
            .iter()
            .enumerate()
            .map(|(i, &w)| {
                let key = if w > 0.0 {
                    self.next_f64().powf(1.0 / w)
                } else {
                    // zero-weight items only picked when everything else ran out
                    -self.next_f64()
                };
                (key, i)
            })
            .collect();
        keys.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        keys.truncate(k);
        keys.into_iter().map(|(_, i)| i).collect()
    }

    /// Weighted sampling WITH replacement (k draws).
    pub fn weighted_sample_with_replacement(
        &mut self,
        weights: &[f64],
        k: usize,
    ) -> Vec<usize> {
        (0..k).map(|_| self.categorical(weights)).collect()
    }

    /// Uniform sampling without replacement: k distinct indices from [0, n).
    /// Floyd's algorithm — O(k) expected, no allocation of [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Largest-remainder multinomial allocation: split `total` into
    /// integer counts proportional to `weights`, capped by `caps`
    /// (available items per bucket). Used for inter-class batch-size
    /// allocation (deterministic part of C-IS; see selection::cis).
    pub fn allocate_proportional(
        &mut self,
        weights: &[f64],
        caps: &[usize],
        total: usize,
    ) -> Vec<usize> {
        allocate_proportional_det(weights, caps, total)
    }
}

/// Deterministic largest-remainder apportionment with caps. Exposed as a
/// free function so selection code can call it without an RNG in hand.
pub fn allocate_proportional_det(
    weights: &[f64],
    caps: &[usize],
    total: usize,
) -> Vec<usize> {
    assert_eq!(weights.len(), caps.len());
    let n = weights.len();
    let mut out = vec![0usize; n];
    if n == 0 || total == 0 {
        return out;
    }
    let capacity: usize = caps.iter().sum();
    let total = total.min(capacity);
    let mass: f64 = weights
        .iter()
        .zip(caps)
        .filter(|(_, &c)| c > 0)
        .map(|(&w, _)| w.max(0.0))
        .sum();
    // Degenerate mass: fall back to caps-proportional (uniform over items).
    let eff: Vec<f64> = if mass <= 0.0 || !mass.is_finite() {
        caps.iter().map(|&c| c as f64).collect()
    } else {
        weights
            .iter()
            .zip(caps)
            .map(|(&w, &c)| if c > 0 { w.max(0.0) } else { 0.0 })
            .collect()
    };
    let eff_mass: f64 = eff.iter().sum();
    if eff_mass <= 0.0 {
        return out;
    }
    // ideal shares, floor, then distribute remainder by largest fraction,
    // respecting caps; iterate because capping can free remainder mass.
    let mut remaining = total;
    let mut active: Vec<usize> = (0..n).filter(|&i| caps[i] > 0 && eff[i] > 0.0).collect();
    while remaining > 0 && !active.is_empty() {
        let m: f64 = active.iter().map(|&i| eff[i]).sum();
        let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(active.len());
        let mut assigned = 0usize;
        for &i in &active {
            let ideal = eff[i] / m * remaining as f64;
            let fl = ideal.floor() as usize;
            let take = fl.min(caps[i] - out[i]);
            out[i] += take;
            assigned += take;
            fracs.push((ideal - fl as f64, i));
        }
        remaining -= assigned;
        if remaining > 0 {
            fracs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
            let mut gave = 0usize;
            for (_, i) in &fracs {
                if remaining == 0 {
                    break;
                }
                if out[*i] < caps[*i] {
                    out[*i] += 1;
                    remaining -= 1;
                    gave += 1;
                }
            }
            if gave == 0 && assigned == 0 {
                break; // everyone saturated
            }
        }
        active.retain(|&i| out[i] < caps[i]);
    }
    // Spill phase: positive-weight buckets saturated but slots remain —
    // fill remaining capacity round-robin (zero-weight buckets included).
    // Without this, a single high-importance class with few candidates
    // would silently shrink the batch (C-IS must always fill |B|).
    while remaining > 0 {
        let mut progressed = false;
        for i in 0..n {
            if remaining == 0 {
                break;
            }
            if out[i] < caps[i] {
                out[i] += 1;
                remaining -= 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 (cross-checked against the
        // published reference implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut r = Xoshiro256::seed_from_u64(99);
        for _ in 0..17 {
            r.next_u64();
        }
        let snap = r.state();
        let want: Vec<u64> = (0..16).map(|_| r.next_u64()).collect();
        let mut restored = Xoshiro256::from_state(snap).unwrap();
        let got: Vec<u64> = (0..16).map(|_| restored.next_u64()).collect();
        assert_eq!(want, got);
        assert!(Xoshiro256::from_state([0; 4]).is_err());
    }

    #[test]
    fn xoshiro_deterministic_and_split_independent() {
        let mut r1 = Xoshiro256::seed_from_u64(42);
        let mut r2 = Xoshiro256::seed_from_u64(42);
        let seq1: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let seq2: Vec<u64> = (0..8).map(|_| r2.next_u64()).collect();
        assert_eq!(seq1, seq2);
        let mut child = r1.split();
        let a: Vec<u64> = (0..8).map(|_| r1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| child.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f32();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn next_below_unbiased_small_n() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.next_below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn categorical_follows_weights() {
        let mut r = Xoshiro256::seed_from_u64(13);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 20_000.0 - 0.6).abs() < 0.03, "{counts:?}");
        assert!((counts[1] as f64 / 20_000.0 - 0.3).abs() < 0.03, "{counts:?}");
    }

    #[test]
    fn categorical_zero_mass_uniform() {
        let mut r = Xoshiro256::seed_from_u64(17);
        let w = [0.0, 0.0, 0.0];
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[r.categorical(&w)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn wswor_distinct_and_weight_biased() {
        let mut r = Xoshiro256::seed_from_u64(19);
        let w = [0.01, 0.01, 10.0, 0.01];
        let mut first_counts = [0usize; 4];
        for _ in 0..2_000 {
            let picks = r.weighted_sample_without_replacement(&w, 2);
            assert_eq!(picks.len(), 2);
            assert_ne!(picks[0], picks[1]);
            first_counts[picks[0]] += 1;
        }
        assert!(first_counts[2] > 1_800, "{first_counts:?}");
    }

    #[test]
    fn wswor_k_geq_n() {
        let mut r = Xoshiro256::seed_from_u64(23);
        let mut got = r.weighted_sample_without_replacement(&[1.0, 2.0], 10);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::seed_from_u64(29);
        for _ in 0..200 {
            let mut got = r.sample_indices(50, 10);
            got.sort_unstable();
            got.dedup();
            assert_eq!(got.len(), 10);
            assert!(got.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(31);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn allocation_exact_and_capped() {
        let out = allocate_proportional_det(&[1.0, 1.0, 2.0], &[10, 10, 10], 8);
        assert_eq!(out.iter().sum::<usize>(), 8);
        assert!(out[2] >= out[0] && out[2] >= out[1], "{out:?}");

        // caps bind: bucket 2 can only take 1
        let out = allocate_proportional_det(&[1.0, 1.0, 100.0], &[10, 10, 1], 8);
        assert_eq!(out[2], 1);
        assert_eq!(out.iter().sum::<usize>(), 8);
    }

    #[test]
    fn allocation_zero_weights_falls_back() {
        let out = allocate_proportional_det(&[0.0, 0.0], &[5, 5], 6);
        assert_eq!(out.iter().sum::<usize>(), 6);
        assert!(out[0] >= 2 && out[1] >= 2, "{out:?}");
    }

    #[test]
    fn allocation_total_exceeds_capacity() {
        let out = allocate_proportional_det(&[1.0, 1.0], &[2, 3], 100);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn allocation_empty() {
        assert!(allocate_proportional_det(&[], &[], 5).is_empty());
        assert_eq!(allocate_proportional_det(&[1.0], &[5], 0), vec![0]);
    }
}
