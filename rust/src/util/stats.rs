//! Small numeric/statistics helpers shared by the selection math, the
//! metrics plane and the bench harness.

/// Left-to-right f64 sum — THE blessed scalar reduction. Callers
/// outside this module and `util::simd` must reduce through these
/// helpers (detlint rule D004), so every sum in the tree shares one
/// pinned association order.
pub fn sum(xs: &[f64]) -> f64 {
    xs.iter().sum()
}

/// Left-to-right fold with `f64::max`, seeded at `init` (blessed; NaN
/// inputs are skipped by `f64::max`'s NaN-losing semantics).
pub fn fold_max(xs: impl IntoIterator<Item = f64>, init: f64) -> f64 {
    xs.into_iter().fold(init, f64::max)
}

/// Left-to-right fold with `f64::min`, seeded at `init` (blessed).
pub fn fold_min(xs: impl IntoIterator<Item = f64>, init: f64) -> f64 {
    xs.into_iter().fold(init, f64::min)
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance (divides by n).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile with linear interpolation, p in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Streaming mean/variance (Welford). Numerically stable, O(1) memory —
/// this is what the coarse filter's running estimators use.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Export the accumulator state `(n, mean, m2)` for checkpointing.
    pub fn state(&self) -> (u64, f64, f64) {
        (self.n, self.mean, self.m2)
    }

    /// Rebuild an accumulator from an exported [`Welford::state`]. The
    /// restored accumulator continues exactly where the exported one
    /// stopped (same internal f64s, so subsequent pushes are bit-identical).
    pub fn from_state(n: u64, mean: f64, m2: f64) -> Welford {
        Welford { n, mean, m2 }
    }
}

/// Streaming mean over f32 vectors (running class centroid).
///
/// Besides the f64 running mean, the struct maintains an f32 cast of the
/// mean and its squared L2 norm **incrementally on every push**, so hot
/// readers ([`VecMean::mean_slice`], [`VecMean::mean_norm2`]) are
/// zero-allocation and O(1) — this is what lets the coarse filter score
/// each streaming sample without materializing a centroid vector.
#[derive(Clone, Debug)]
pub struct VecMean {
    n: u64,
    mean: Vec<f64>,
    /// f32 cast of `mean`, kept in lockstep (what scoring consumes).
    mean_f32: Vec<f32>,
    /// `simd::norm2(&mean_f32)`, refreshed inside the fused wide push
    /// ([`crate::util::simd::mean_update`]) with exactly the 8-lane
    /// striping of [`crate::util::simd::norm2`], so cached and
    /// from-scratch values agree bit-for-bit.
    mean_norm2: f64,
}

impl VecMean {
    pub fn new(dim: usize) -> Self {
        Self {
            n: 0,
            mean: vec![0.0; dim],
            mean_f32: vec![0.0; dim],
            mean_norm2: 0.0,
        }
    }

    pub fn push(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.mean.len());
        self.n += 1;
        let inv = 1.0 / self.n as f64;
        // fused wide update: one 8-lane pass advances the f64 mean, its
        // f32 cast, and the cached ‖mean_f32‖² (striped exactly like
        // `simd::norm2`, so the cache matches a from-scratch wide norm
        // bit-for-bit — the coherence `simd` property-tests)
        self.mean_norm2 =
            crate::util::simd::mean_update(&mut self.mean, &mut self.mean_f32, x, inv);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean_f32(&self) -> Vec<f32> {
        self.mean_f32.clone()
    }

    /// Borrowed view of the current mean (f32 cast) — no allocation.
    pub fn mean_slice(&self) -> &[f32] {
        &self.mean_f32
    }

    /// Cached `‖mean‖²` of the f32-cast mean — no allocation, no O(dim)
    /// recompute. Identical to `simd::norm2(&self.mean_f32())` (the wide
    /// kernel; within 1e-12 of the scalar [`norm2`]).
    pub fn mean_norm2(&self) -> f64 {
        self.mean_norm2
    }

    /// Export `(count, f64 mean)` — the minimal state that determines the
    /// whole accumulator (the f32 cast and its cached norm are derived).
    pub fn state(&self) -> (u64, &[f64]) {
        (self.n, &self.mean)
    }

    /// Rebuild from an exported [`VecMean::state`]. The f32 cast is
    /// re-derived elementwise and the cached `‖mean‖²` is recomputed with
    /// the same wide kernel the push loop stripes by, so the restored
    /// accumulator is bit-identical to the exported one.
    pub fn from_state(n: u64, mean: Vec<f64>) -> VecMean {
        let mean_f32: Vec<f32> = mean.iter().map(|&m| m as f32).collect();
        let mean_norm2 = crate::util::simd::norm2(&mean_f32);
        VecMean { n, mean, mean_f32, mean_norm2 }
    }
}

/// Exponential moving average.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Scalar dot product of two f32 slices (f64 left-to-right accumulation).
/// Reference oracle for [`crate::util::simd::dot`] — hot paths use the
/// wide kernel.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Scalar squared L2 norm of an f32 slice (f64 left-to-right
/// accumulation). Reference oracle for [`crate::util::simd::norm2`] —
/// hot paths use the wide kernel.
pub fn norm2(a: &[f32]) -> f64 {
    a.iter().map(|&x| x as f64 * x as f64).sum()
}

/// Squared L2 distance between two f32 slices.
pub fn dist2(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blessed_reductions() {
        let xs = [2.0, -1.0, 4.5];
        assert_eq!(sum(&xs), 5.5);
        assert_eq!(fold_max(xs.iter().copied(), f64::NEG_INFINITY), 4.5);
        assert_eq!(fold_min(xs.iter().copied(), f64::INFINITY), -1.0);
        assert_eq!(sum(&[]), 0.0);
        assert_eq!(fold_max(std::iter::empty(), 0.0), 0.0);
    }

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[5.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37 - 5.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-9);
        assert!((w.variance() - variance(&xs)).abs() < 1e-9);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn vec_mean_matches() {
        let mut vm = VecMean::new(3);
        vm.push(&[1.0, 0.0, 2.0]);
        vm.push(&[3.0, 0.0, 4.0]);
        let m = vm.mean_f32();
        assert_eq!(m, vec![2.0, 0.0, 3.0]);
        assert_eq!(vm.mean_slice(), &m[..]);
    }

    #[test]
    fn vec_mean_cached_norm2_is_bit_identical() {
        // the cached norm must equal a from-scratch wide norm2 over the
        // f32 cast EXACTLY (same striping), and stay within 1e-12 of the
        // scalar reference — at remainder-lane dims too (5, 8, 9, 63)
        for dim in [5usize, 8, 9, 63] {
            let mut vm = VecMean::new(dim);
            assert_eq!(vm.mean_norm2(), 0.0);
            let mut state = 1u64 + dim as u64;
            for _ in 0..100 {
                let x: Vec<f32> = (0..dim)
                    .map(|_| {
                        state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((state >> 33) as f32 / 2.0e9f32) - 1.0
                    })
                    .collect();
                vm.push(&x);
                let wide = crate::util::simd::norm2(&vm.mean_f32());
                assert_eq!(vm.mean_norm2().to_bits(), wide.to_bits(), "dim {dim}");
                let scalar = norm2(&vm.mean_f32());
                assert!(
                    (vm.mean_norm2() - scalar).abs() <= 1e-12 * scalar.max(1.0),
                    "dim {dim}: cached {} vs scalar {scalar}",
                    vm.mean_norm2()
                );
            }
        }
    }

    #[test]
    fn vec_mean_state_roundtrip_is_bit_identical() {
        let mut vm = VecMean::new(4);
        let mut state = 7u64;
        let draw = |state: &mut u64| -> Vec<f32> {
            (0..4)
                .map(|_| {
                    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((*state >> 33) as f32 / 2.0e9f32) - 1.0
                })
                .collect()
        };
        for _ in 0..37 {
            vm.push(&draw(&mut state));
        }
        let (n, mean) = vm.state();
        let mut restored = VecMean::from_state(n, mean.to_vec());
        assert_eq!(restored.count(), vm.count());
        assert_eq!(restored.mean_slice(), vm.mean_slice());
        assert_eq!(restored.mean_norm2(), vm.mean_norm2());
        // subsequent pushes continue bit-identically
        for _ in 0..11 {
            let x = draw(&mut state);
            vm.push(&x);
            restored.push(&x);
        }
        assert_eq!(restored.mean_slice(), vm.mean_slice());
        assert_eq!(restored.mean_norm2(), vm.mean_norm2());
    }

    #[test]
    fn welford_state_roundtrip() {
        let mut w = Welford::new();
        for i in 0..50 {
            w.push(i as f64 * 0.13 - 2.0);
        }
        let (n, m, m2) = w.state();
        let mut restored = Welford::from_state(n, m, m2);
        assert_eq!(restored.count(), w.count());
        assert_eq!(restored.mean(), w.mean());
        assert_eq!(restored.variance(), w.variance());
        w.push(1.5);
        restored.push(1.5);
        assert_eq!(restored.mean(), w.mean());
        assert_eq!(restored.variance(), w.variance());
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.push(10.0), 10.0);
        let v = e.push(0.0);
        assert_eq!(v, 5.0);
        assert_eq!(e.get(), Some(5.0));
    }

    #[test]
    fn vector_ops() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(norm2(&a), 14.0);
        assert_eq!(dist2(&a, &b), 27.0);
    }
}
