//! IS — importance sampling (Katharopoulos & Fleuret '18; Zhao & Zhang
//! '15). Selects each sample with probability proportional to its
//! last-layer gradient norm, jointly over the whole candidate set.
//!
//! This is the strategy Lemma 1 shows to be optimal for *sample-level*
//! selection but sub-optimal at *batch level*: allocating by gradient norm
//! alone ignores the class-variance term γ_y that C-IS restores (Thm. 2).

use super::{make_weights, SelectedBatch, SelectionContext, SelectionStrategy};
use crate::util::rng::Xoshiro256;
use crate::Result;

pub struct ImportanceSampling;

impl SelectionStrategy for ImportanceSampling {
    fn name(&self) -> &'static str {
        "is"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Xoshiro256) -> Result<SelectedBatch> {
        let imp = ctx.require_importance()?;
        let probs: Vec<f64> = imp.norms.iter().map(|&n| n.max(0.0) as f64).collect();
        let total: f64 = probs.iter().sum();
        let indices = rng.weighted_sample_without_replacement(&probs, ctx.batch);
        // unbiasedness: w_i = 1/(n·P(i)) with P(i) = norm_i / Σnorms
        let n = ctx.n() as f64;
        let inv: Vec<f64> = indices
            .iter()
            .map(|&i| {
                if total > 0.0 && probs[i] > 0.0 {
                    total / (n * probs[i])
                } else {
                    1.0
                }
            })
            .collect();
        Ok(SelectedBatch {
            weights: make_weights(&inv),
            indices,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::testutil::{assert_valid_batch, candidates, importance_from_grads};

    #[test]
    fn prefers_high_norm_samples() {
        let cands = candidates(20, 2, 5);
        let refs: Vec<&_> = cands.iter().collect();
        // samples 0..10 have tiny gradients, 10..20 large
        let grads: Vec<(f64, f64)> = (0..20)
            .map(|i| if i < 10 { (0.01, 0.0) } else { (5.0, 1.0) })
            .collect();
        let imp = importance_from_grads(&grads);
        let seen = vec![10u64; 6];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 6,
            batch: 5,
            importance: Some(&imp),
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(6);
        let mut strat = ImportanceSampling;
        let mut high = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            let sel = strat.select(&ctx, &mut rng).unwrap();
            assert_valid_batch(&sel, 20, 5);
            // rarely-picked low-norm samples get up-weighted when they do
            // appear; high-norm picks get down-weighted
            for (k, &i) in sel.indices.iter().enumerate() {
                if i < 10 {
                    assert!(sel.weights[k] >= 1.0, "{:?}", sel.weights);
                }
            }
            high += sel.indices.iter().filter(|&&i| i >= 10).count();
            total += sel.indices.len();
        }
        assert!(
            high as f64 / total as f64 > 0.9,
            "high-norm fraction {high}/{total}"
        );
    }

    #[test]
    fn errors_without_importance_evidence() {
        let cands = candidates(5, 2, 7);
        let refs: Vec<&_> = cands.iter().collect();
        let seen = vec![1u64; 6];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 6,
            batch: 2,
            importance: None,
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(8);
        assert!(ImportanceSampling.select(&ctx, &mut rng).is_err());
    }
}
