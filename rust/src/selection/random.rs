//! RS — random selection. The paper's normalization baseline (Table 1
//! times are reported relative to it).

use super::{SelectedBatch, SelectionContext, SelectionStrategy};
use crate::util::rng::Xoshiro256;
use crate::Result;

pub struct RandomSelection;

impl SelectionStrategy for RandomSelection {
    fn name(&self) -> &'static str {
        "rs"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Xoshiro256) -> Result<SelectedBatch> {
        // uniform sampling is already unbiased: unit weights
        Ok(SelectedBatch::unweighted(
            rng.sample_indices(ctx.n(), ctx.batch),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::testutil::{assert_valid_batch, candidates};

    #[test]
    fn picks_valid_batches() {
        let cands = candidates(30, 6, 1);
        let refs: Vec<&_> = cands.iter().collect();
        let seen = vec![10u64; 6];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 6,
            batch: 10,
            importance: None,
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut strat = RandomSelection;
        for _ in 0..20 {
            let sel = strat.select(&ctx, &mut rng).unwrap();
            assert_valid_batch(&sel, 30, 10);
            assert!(sel.weights.iter().all(|&w| w == 1.0));
        }
    }

    #[test]
    fn covers_all_candidates_over_many_rounds() {
        let cands = candidates(15, 3, 2);
        let refs: Vec<&_> = cands.iter().collect();
        let seen = vec![5u64; 6];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 6,
            batch: 5,
            importance: None,
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut hit = vec![false; 15];
        let mut strat = RandomSelection;
        for _ in 0..100 {
            for i in strat.select(&ctx, &mut rng).unwrap().indices {
                hit[i] = true;
            }
        }
        assert!(hit.iter().all(|&h| h), "{hit:?}");
    }
}
