//! C-IS — classified importance sampling, the paper's fine-grained
//! selection strategy (§3.2, Lemma 2) and the core of Titan.
//!
//! Two stages:
//!
//! 1. **Inter-class batch-size allocation** — slots per class proportional
//!    to the class importance
//!
//!    `I_t(y) = |S_y| * sqrt( V[∇l] - V[‖∇l‖] )`            (Eq. 2)
//!
//!    which, expanded (see Lemma 2's proof: β*_y − γ_y), equals
//!
//!    `I_t(y) = |S_y| * sqrt( (E‖g‖)² − ‖E g‖² )`
//!
//!    — both moments estimated from the candidates of class y via the
//!    Gram matrix K: `E‖g‖ = mean(sqrt(K_ii))`, `‖E g‖² = Σ_ij K_ij / n²`.
//!
//! 2. **Intra-class selection** — within class y, sample `|B_y|` items
//!    without replacement with probability ∝ ‖∇l‖ (Eq. 3), i.e. IS
//!    restricted to the class.
//!
//! The difference from plain IS is exactly the allocation: IS spends
//! slots on classes with large gradient *norms*; C-IS spends them on
//! classes whose gradients are *diverse but uniformly sized* (Fig. 4).
//! Finite-sample guardrails: the variance difference is clamped at 0 and
//! an all-zero importance vector falls back to candidate-count-
//! proportional allocation (DESIGN.md §Discrepancies #2).

use super::{make_weights, SelectedBatch, SelectionContext, SelectionStrategy};
use crate::runtime::model::ImportanceOut;
use crate::util::rng::{allocate_proportional_det, Xoshiro256};
use crate::Result;

/// Per-class summary extracted from K (also used by variance.rs and the
/// Fig. 5 experiments).
#[derive(Clone, Debug)]
pub struct ClassSummary {
    /// Candidate indices of this class.
    pub indices: Vec<usize>,
    /// `K_ii = ‖g_i‖²` per candidate, aligned with `indices` — carried so
    /// the Theorem-2 variance analysis reads the diagonal from the summary
    /// instead of re-walking K.
    pub diag: Vec<f64>,
    /// mean ‖g‖ over the class candidates.
    pub mean_norm: f64,
    /// mean ‖g‖² (= mean K_ii).
    pub mean_norm2: f64,
    /// ‖mean g‖² (= Σ_ij K_ij / n²).
    pub mean_grad_norm2: f64,
}

impl ClassSummary {
    /// V[∇l] — gradient variance of the class candidates.
    pub fn grad_variance(&self) -> f64 {
        (self.mean_norm2 - self.mean_grad_norm2).max(0.0)
    }

    /// V[‖∇l‖] — gradient-*norm* variance.
    pub fn norm_variance(&self) -> f64 {
        (self.mean_norm2 - self.mean_norm * self.mean_norm).max(0.0)
    }

    /// The Eq. 2 inner term, clamped: V[∇l] − V[‖∇l‖] = (E‖g‖)² − ‖Eg‖².
    pub fn diversity(&self) -> f64 {
        (self.mean_norm * self.mean_norm - self.mean_grad_norm2).max(0.0)
    }
}

/// Summarize the candidate classes from the importance output.
///
/// Built on [`ImportanceOut::gram_class_sums`]: ONE sweep over K's upper
/// triangle yields every class's diagonal/norm/block sums simultaneously,
/// replacing the old per-class nested `k_at` loops (O(C·n²) scalar reads,
/// cache-hostile). Below the sweep's blocking threshold (every pinned run
/// configuration) the per-class accumulation order is unchanged, so
/// results are bit-identical to [`class_summaries_ref`]. Single-threaded
/// alias of [`class_summaries_threaded`].
pub fn class_summaries(
    ctx_labels: &[u32],
    imp: &ImportanceOut,
    num_classes: usize,
) -> Vec<ClassSummary> {
    class_summaries_threaded(ctx_labels, imp, num_classes, 1)
}

/// [`class_summaries`] over the parallel triangle sweep
/// ([`ImportanceOut::gram_class_sums_threaded`]) — summaries are
/// bit-identical for every `threads` value (the sweep's block partition
/// depends only on the candidate count), so the knob is purely a
/// wall-clock lever for `cand_max ≥ 4k` deployments.
pub fn class_summaries_threaded(
    ctx_labels: &[u32],
    imp: &ImportanceOut,
    num_classes: usize,
    threads: usize,
) -> Vec<ClassSummary> {
    let sums = imp.gram_class_sums_threaded(ctx_labels, num_classes, threads);
    let crate::runtime::model::GramClassSums {
        num_classes: c,
        indices,
        sum_norm,
        sum_diag,
        block,
        diag,
    } = sums;
    indices
        .into_iter()
        .enumerate()
        .map(|(y, indices)| {
            let n = indices.len();
            if n == 0 {
                return ClassSummary {
                    indices,
                    diag: Vec::new(),
                    mean_norm: 0.0,
                    mean_norm2: 0.0,
                    mean_grad_norm2: 0.0,
                };
            }
            let nf = n as f64;
            let class_diag: Vec<f64> = indices.iter().map(|&i| diag[i]).collect();
            ClassSummary {
                indices,
                diag: class_diag,
                mean_norm: sum_norm[y] / nf,
                mean_norm2: sum_diag[y] / nf,
                mean_grad_norm2: block[y * c + y] / (nf * nf),
            }
        })
        .collect()
}

/// Scalar reference implementation of [`class_summaries`] — the original
/// per-class nested `k_at` loops. Kept as the equivalence oracle for the
/// property tests and the old-vs-new benches; not for production use.
pub fn class_summaries_ref(
    ctx_labels: &[u32],
    imp: &ImportanceOut,
    num_classes: usize,
) -> Vec<ClassSummary> {
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &y) in ctx_labels.iter().enumerate().take(imp.valid) {
        by_class[y as usize].push(i);
    }
    by_class
        .into_iter()
        .map(|indices| {
            let n = indices.len();
            if n == 0 {
                return ClassSummary {
                    indices,
                    diag: Vec::new(),
                    mean_norm: 0.0,
                    mean_norm2: 0.0,
                    mean_grad_norm2: 0.0,
                };
            }
            let mut sum_norm = 0.0f64;
            let mut sum_diag = 0.0f64;
            let mut sum_all = 0.0f64;
            let mut diag = Vec::with_capacity(n);
            for (a, &i) in indices.iter().enumerate() {
                // detlint: allow(D004) Theorem-2 class summary: index-ordered reduction, pinned
                // by the CIS equivalence tests (same order on every backend)
                sum_norm += imp.norms[i] as f64;
                // detlint: allow(D004) see above: pinned index-ordered reduction
                sum_diag += imp.k_at(i, i) as f64;
                diag.push(imp.k_at(i, i) as f64);
                // off-diagonal: use symmetry, accumulate full sum
                // detlint: allow(D004) see above: pinned index-ordered reduction
                sum_all += imp.k_at(i, i) as f64;
                for &j in &indices[a + 1..] {
                    // detlint: allow(D004) see above: pinned index-ordered reduction
                    sum_all += 2.0 * imp.k_at(i, j) as f64;
                }
            }
            let nf = n as f64;
            ClassSummary {
                indices,
                diag,
                mean_norm: sum_norm / nf,
                mean_norm2: sum_diag / nf,
                mean_grad_norm2: sum_all / (nf * nf),
            }
        })
        .collect()
}

/// Class importance I_t(y) per Eq. 2 given the stream frequencies |S_y|.
pub fn class_importances(summaries: &[ClassSummary], seen_per_class: &[u64]) -> Vec<f64> {
    summaries
        .iter()
        .enumerate()
        .map(|(y, s)| {
            if s.indices.is_empty() {
                0.0
            } else {
                seen_per_class.get(y).copied().unwrap_or(0) as f64 * s.diversity().sqrt()
            }
        })
        .collect()
}

pub struct ClassifiedImportanceSampling {
    /// Worker threads for the Gram triangle sweep (`RunConfig::
    /// select_threads`; 1 = sweep on the calling thread). Results are
    /// identical for every value — see [`class_summaries_threaded`].
    threads: usize,
}

impl ClassifiedImportanceSampling {
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }
}

impl Default for ClassifiedImportanceSampling {
    fn default() -> Self {
        Self::new(1)
    }
}

impl SelectionStrategy for ClassifiedImportanceSampling {
    fn name(&self) -> &'static str {
        "cis"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Xoshiro256) -> Result<SelectedBatch> {
        let imp = ctx.require_importance()?;
        let labels: Vec<u32> = ctx.samples.iter().map(|s| s.label).collect();
        let summaries = class_summaries_threaded(&labels, imp, ctx.num_classes, self.threads);
        let importances = class_importances(&summaries, ctx.seen_per_class);
        let caps: Vec<usize> = summaries.iter().map(|s| s.indices.len()).collect();
        // Inter-class allocation (largest-remainder, caps = candidates/class;
        // zero-importance vectors fall back to caps-proportional inside).
        let alloc = allocate_proportional_det(&importances, &caps, ctx.batch);
        // Intra-class IS without replacement + per-sample unbiasedness
        // weights: w_i = B / (n · |B_y| · P_y(i)), P_y(i) = norm_i/Σ_y norms
        // (Appendix A.2 eq. (f), with the candidate set standing in for S).
        let n = ctx.n() as f64;
        let b = ctx.batch as f64;
        let mut picks = Vec::with_capacity(ctx.batch);
        let mut inv = Vec::with_capacity(ctx.batch);
        for (y, &take) in alloc.iter().enumerate() {
            if take == 0 {
                continue;
            }
            let s = &summaries[y];
            let probs: Vec<f64> = s
                .indices
                .iter()
                .map(|&i| (imp.norms[i] as f64).max(0.0))
                .collect();
            let class_total: f64 = probs.iter().sum();
            for local in rng.weighted_sample_without_replacement(&probs, take) {
                picks.push(s.indices[local]);
                inv.push(if class_total > 0.0 && probs[local] > 0.0 {
                    b * class_total / (n * take as f64 * probs[local])
                } else {
                    1.0
                });
            }
        }
        Ok(SelectedBatch {
            weights: make_weights(&inv),
            indices: picks,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::testutil::{assert_valid_batch, candidates, importance_from_grads};
    use crate::selection::SelectionContext;

    /// Build the paper's Fig. 4 scenario: class 0 has diverse gradients of
    /// equal norm (high importance), class 1 has identical gradients (zero
    /// diversity), equal average norms.
    fn fig4_importance(n_per_class: usize) -> (Vec<(f64, f64)>, usize) {
        let mut grads = Vec::new();
        for i in 0..n_per_class {
            // class 0: unit vectors fanned over the circle — ‖g‖=1, diverse
            let th = i as f64 / n_per_class as f64 * std::f64::consts::PI;
            grads.push((th.cos(), th.sin()));
        }
        for _ in 0..n_per_class {
            // class 1: all identical unit vectors — same mean norm, no diversity
            grads.push((1.0, 0.0));
        }
        (grads, n_per_class)
    }

    #[test]
    fn class_summaries_match_hand_computation() {
        let (grads, npc) = fig4_importance(8);
        let imp = importance_from_grads(&grads);
        let labels: Vec<u32> = (0..16).map(|i| (i / npc) as u32).collect();
        let s = class_summaries(&labels, &imp, 2);
        // class 0: all norms 1
        assert!((s[0].mean_norm - 1.0).abs() < 1e-5, "{}", s[0].mean_norm);
        assert!((s[0].mean_norm2 - 1.0).abs() < 1e-5);
        assert!(s[0].mean_grad_norm2 < 0.7, "diverse class: ‖Eg‖² small");
        assert!(s[0].diversity() > 0.3);
        // class 1: identical gradients -> ‖Eg‖² = 1, diversity 0
        assert!((s[1].mean_grad_norm2 - 1.0).abs() < 1e-4);
        assert!(s[1].diversity() < 1e-6);
        // variance identities
        assert!((s[1].grad_variance()).abs() < 1e-5);
        assert!((s[0].norm_variance()).abs() < 1e-5, "equal norms");
    }

    /// Assert two summary vectors agree within `tol` (relative).
    fn assert_summaries_close(a: &[ClassSummary], b: &[ClassSummary], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (y, (x, r)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.indices, r.indices, "class {y} indices");
            assert_eq!(x.diag.len(), r.diag.len(), "class {y} diag len");
            for (d, e) in x.diag.iter().zip(&r.diag) {
                assert!((d - e).abs() <= tol * e.abs().max(1.0), "class {y} diag {d} vs {e}");
            }
            for (name, u, v) in [
                ("mean_norm", x.mean_norm, r.mean_norm),
                ("mean_norm2", x.mean_norm2, r.mean_norm2),
                ("mean_grad_norm2", x.mean_grad_norm2, r.mean_grad_norm2),
            ] {
                assert!(
                    (u - v).abs() <= tol * v.abs().max(1.0),
                    "class {y} {name}: {u} vs {v}"
                );
            }
        }
    }

    #[test]
    fn property_single_pass_matches_reference() {
        // the single-pass triangle sweep must agree with the per-class
        // nested reference within 1e-12 on random geometries (in fact the
        // accumulation order is identical, so they match bit-for-bit)
        crate::util::prop::forall(
            61,
            40,
            |rng| crate::util::prop::gen::f64_vec(rng, 3, 3, 0.0, 1.0),
            |seedvec| {
                let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(
                    (seedvec.iter().sum::<f64>() * 1e6) as u64 + 11,
                );
                let c = 1 + rng.index(5);
                let n = 1 + rng.index(40);
                let grads: Vec<(f64, f64)> = (0..n)
                    .map(|_| (rng.next_f64() * 4.0 - 2.0, rng.next_f64() * 4.0 - 2.0))
                    .collect();
                let labels: Vec<u32> = (0..n).map(|_| rng.index(c) as u32).collect();
                let imp = importance_from_grads(&grads);
                let fast = class_summaries(&labels, &imp, c);
                let slow = class_summaries_ref(&labels, &imp, c);
                for (y, (x, r)) in fast.iter().zip(&slow).enumerate() {
                    if x.indices != r.indices || x.diag != r.diag {
                        return Err(format!("class {y} indices/diag diverged"));
                    }
                    for (u, v) in [
                        (x.mean_norm, r.mean_norm),
                        (x.mean_norm2, r.mean_norm2),
                        (x.mean_grad_norm2, r.mean_grad_norm2),
                    ] {
                        if (u - v).abs() > 1e-12 * v.abs().max(1.0) {
                            return Err(format!("class {y}: {u} vs {v}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn regression_fig4_summaries_unchanged() {
        // the Fig. 4 scenario must produce the exact same summaries through
        // the single-pass path as through the original reference path
        let (grads, npc) = fig4_importance(10);
        let imp = importance_from_grads(&grads);
        let labels: Vec<u32> = (0..20).map(|i| (i / npc) as u32).collect();
        let fast = class_summaries(&labels, &imp, 2);
        let slow = class_summaries_ref(&labels, &imp, 2);
        assert_summaries_close(&fast, &slow, 1e-12);
        // and the derived quantities the allocation consumes are unchanged
        let i_fast = class_importances(&fast, &[100, 100]);
        let i_slow = class_importances(&slow, &[100, 100]);
        for (a, b) in i_fast.iter().zip(&i_slow) {
            assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    /// Summaries-level cross-`select_threads` pin at sub-blocking size:
    /// any thread count must yield bit-identical ClassSummary fields
    /// (the large multi-block pin lives in runtime::model's tests).
    #[test]
    fn summaries_bit_identical_across_thread_counts() {
        let (grads, npc) = fig4_importance(12);
        let imp = importance_from_grads(&grads);
        let labels: Vec<u32> = (0..24).map(|i| (i / npc) as u32).collect();
        let one = class_summaries_threaded(&labels, &imp, 2, 1);
        for threads in [2usize, 4, 32] {
            let many = class_summaries_threaded(&labels, &imp, 2, threads);
            assert_eq!(one.len(), many.len());
            for (a, b) in one.iter().zip(&many) {
                assert_eq!(a.indices, b.indices, "t={threads}");
                assert_eq!(a.diag, b.diag, "t={threads}");
                assert_eq!(a.mean_norm.to_bits(), b.mean_norm.to_bits(), "t={threads}");
                assert_eq!(a.mean_norm2.to_bits(), b.mean_norm2.to_bits(), "t={threads}");
                assert_eq!(
                    a.mean_grad_norm2.to_bits(),
                    b.mean_grad_norm2.to_bits(),
                    "t={threads}"
                );
            }
        }
    }

    #[test]
    fn fig4_allocation_prefers_diverse_class() {
        // THE paper's key qualitative claim (Fig. 4): C-IS sends more slots
        // to the diverse class; IS would split evenly (equal norms).
        let (grads, npc) = fig4_importance(10);
        let imp = importance_from_grads(&grads);
        let cands = candidates(20, 2, 11);
        let refs: Vec<&_> = cands.iter().collect();
        // relabel candidates to match grads: first npc class 0, rest class 1
        let mut owned: Vec<_> = cands.clone();
        for (i, s) in owned.iter_mut().enumerate() {
            s.label = (i / npc) as u32;
        }
        let refs2: Vec<&_> = owned.iter().collect();
        let _ = refs;
        let seen = vec![100u64, 100u64];
        let ctx = SelectionContext {
            samples: &refs2,
            seen_per_class: &seen,
            num_classes: 2,
            batch: 10,
            importance: Some(&imp),
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(12);
        let mut strat = ClassifiedImportanceSampling::default();
        let mut class0 = 0usize;
        let mut total = 0usize;
        for _ in 0..50 {
            let picks = strat.select(&ctx, &mut rng).unwrap();
            assert_valid_batch(&picks, 20, 10);
            class0 += picks.indices.iter().filter(|&&i| owned[i].label == 0).count();
            total += picks.indices.len();
        }
        let frac = class0 as f64 / total as f64;
        assert!(frac > 0.8, "diverse-class fraction {frac}");
    }

    #[test]
    fn zero_importance_falls_back_to_proportional() {
        // all classes zero diversity -> proportional to candidate counts
        let grads: Vec<(f64, f64)> = (0..12).map(|_| (1.0, 0.0)).collect();
        let imp = importance_from_grads(&grads);
        let mut owned = candidates(12, 3, 13);
        for (i, s) in owned.iter_mut().enumerate() {
            s.label = (i % 3) as u32;
        }
        let refs: Vec<&_> = owned.iter().collect();
        let seen = vec![10u64; 3];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 3,
            batch: 6,
            importance: Some(&imp),
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(14);
        let picks = ClassifiedImportanceSampling::default().select(&ctx, &mut rng).unwrap();
        assert_valid_batch(&picks, 12, 6);
        let mut per_class = [0usize; 3];
        for &i in &picks.indices {
            per_class[owned[i].label as usize] += 1;
        }
        assert_eq!(per_class, [2, 2, 2], "{per_class:?}");
    }

    #[test]
    fn importance_scales_with_stream_frequency() {
        let (grads, _) = fig4_importance(5);
        let imp = importance_from_grads(&grads);
        let labels: Vec<u32> = (0..10).map(|i| (i / 5) as u32).collect();
        let summaries = class_summaries(&labels, &imp, 2);
        let i_small = class_importances(&summaries, &[10, 10]);
        let i_big = class_importances(&summaries, &[100, 10]);
        assert!(i_big[0] > i_small[0] * 5.0);
        assert_eq!(i_small[1], 0.0, "zero-diversity class has zero importance");
    }

    #[test]
    fn respects_class_caps() {
        // class 0 has 2 candidates but huge importance — allocation must
        // not exceed the cap and must fill the rest from class 1
        let mut grads: Vec<(f64, f64)> = vec![(0.0, 1.0), (1.0, 0.0)]; // diverse
        grads.extend((0..8).map(|_| (0.5, 0.0))); // identical
        let imp = importance_from_grads(&grads);
        let mut owned = candidates(10, 2, 15);
        for (i, s) in owned.iter_mut().enumerate() {
            s.label = if i < 2 { 0 } else { 1 };
        }
        let refs: Vec<&_> = owned.iter().collect();
        let seen = vec![1000u64, 10u64];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 2,
            batch: 6,
            importance: Some(&imp),
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(16);
        let picks = ClassifiedImportanceSampling::default().select(&ctx, &mut rng).unwrap();
        assert_valid_batch(&picks, 10, 6);
        let c0 = picks.indices.iter().filter(|&&i| owned[i].label == 0).count();
        assert_eq!(c0, 2, "cap bound");
    }

    #[test]
    fn empty_class_handled() {
        let grads: Vec<(f64, f64)> = (0..6).map(|i| (i as f64 * 0.3, 1.0)).collect();
        let imp = importance_from_grads(&grads);
        let mut owned = candidates(6, 2, 17);
        for s in owned.iter_mut() {
            s.label = 0; // class 1 empty
        }
        let refs: Vec<&_> = owned.iter().collect();
        let seen = vec![10u64, 10u64];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 2,
            batch: 4,
            importance: Some(&imp),
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(18);
        let picks = ClassifiedImportanceSampling::default().select(&ctx, &mut rng).unwrap();
        assert_valid_batch(&picks, 6, 4);
    }
}
