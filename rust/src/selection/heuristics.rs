//! HDS — heuristic data-selection baselines (§2.3 of the paper):
//!
//! - [`LossBased`] `high: true` = **HL** (highest per-sample loss,
//!   selection-via-proxy style) / `high: false` = **LL** (lowest loss,
//!   robust-SGD style).
//! - [`EntropyBased`] = **CE** (highest output-distribution entropy, the
//!   classic active-learning uncertainty score).
//! - [`RepDiv`] = **OCS** (representativeness + diversity in feature
//!   space, online-coreset style).
//!
//! These optimize proxy objectives, not the training-performance objective
//! — the paper's point is precisely that they underperform at small batch
//! sizes. They are deterministic top-k selectors (as deployed in their
//! source papers).

use super::{SelectedBatch, SelectionContext, SelectionStrategy};
use crate::util::rng::Xoshiro256;
use crate::util::stats;
use crate::Result;

/// Deterministic top-k by score (desc), tie-broken by index for
/// reproducibility. NaN scores (e.g. probe loss on a diverged model) sort
/// last — `total_cmp` keeps the comparator a total order.
fn top_k_by(scores: &[f64], k: usize) -> Vec<usize> {
    let sane = |s: f64| if s.is_nan() { f64::NEG_INFINITY } else { s };
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        sane(scores[b])
            .total_cmp(&sane(scores[a]))
            .then_with(|| a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// LL / HL.
pub struct LossBased {
    pub high: bool,
}

impl SelectionStrategy for LossBased {
    fn name(&self) -> &'static str {
        if self.high {
            "hl"
        } else {
            "ll"
        }
    }

    fn select(&mut self, ctx: &SelectionContext, _rng: &mut Xoshiro256) -> Result<SelectedBatch> {
        let probe = ctx.require_probe()?;
        let scores: Vec<f64> = probe.loss[..ctx.n()]
            .iter()
            .map(|&l| if self.high { l as f64 } else { -(l as f64) })
            .collect();
        Ok(SelectedBatch::unweighted(top_k_by(&scores, ctx.batch)))
    }
}

/// CE — output entropy.
pub struct EntropyBased;

impl SelectionStrategy for EntropyBased {
    fn name(&self) -> &'static str {
        "ce"
    }

    fn select(&mut self, ctx: &SelectionContext, _rng: &mut Xoshiro256) -> Result<SelectedBatch> {
        let probe = ctx.require_probe()?;
        let scores: Vec<f64> = probe.entropy[..ctx.n()].iter().map(|&e| e as f64).collect();
        Ok(SelectedBatch::unweighted(top_k_by(&scores, ctx.batch)))
    }
}

/// OCS — representativeness + diversity over features.
///
/// Greedy: repeatedly add the candidate maximizing
/// `closeness-to-class-centroid + distance-to-already-selected`, the
/// standard rep/div trade-off of online coreset selection.
pub struct RepDiv;

impl SelectionStrategy for RepDiv {
    fn name(&self) -> &'static str {
        "ocs"
    }

    fn select(&mut self, ctx: &SelectionContext, _rng: &mut Xoshiro256) -> Result<SelectedBatch> {
        let n = ctx.n();
        let d = ctx.feature_dim;
        let feats = ctx
            .features
            .ok_or_else(|| crate::Error::Other("ocs requires features".into()))?;
        // per-class centroids over the candidates
        let by_class = ctx.class_indices();
        let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(by_class.len());
        for idxs in &by_class {
            let mut c = vec![0.0f32; d];
            if !idxs.is_empty() {
                for &i in idxs {
                    for (cc, &v) in c.iter_mut().zip(&feats[i * d..(i + 1) * d]) {
                        *cc += v / idxs.len() as f32;
                    }
                }
            }
            centroids.push(c);
        }
        let rep: Vec<f64> = (0..n)
            .map(|i| {
                let y = ctx.samples[i].label as usize;
                -stats::dist2(&feats[i * d..(i + 1) * d], &centroids[y])
            })
            .collect();
        // normalize rep to unit scale so rep and div are commensurate
        let rep_scale = stats::fold_max(rep.iter().map(|r| r.abs()), 0.0).max(1e-9);
        let mut chosen: Vec<usize> = Vec::with_capacity(ctx.batch);
        let mut remaining: Vec<usize> = (0..n).collect();
        while chosen.len() < ctx.batch.min(n) {
            let mut best = remaining[0];
            let mut best_score = f64::NEG_INFINITY;
            for &i in &remaining {
                let div = if chosen.is_empty() {
                    0.0
                } else {
                    let mut dsum = 0.0;
                    for &j in &chosen {
                        // detlint: allow(D004) diversity term summed in chosen order (greedy order
                        // is part of the algorithm, so the fold order is already pinned)
                        dsum += stats::dist2(
                            &feats[i * d..(i + 1) * d],
                            &feats[j * d..(j + 1) * d],
                        );
                    }
                    dsum / chosen.len() as f64
                };
                let div_scale = rep_scale; // same normalization
                let score = rep[i] / rep_scale + div / div_scale;
                if score > best_score {
                    best_score = score;
                    best = i;
                }
            }
            chosen.push(best);
            remaining.retain(|&i| i != best);
        }
        Ok(SelectedBatch::unweighted(chosen))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::testutil::{assert_valid_batch, candidates};
    use crate::selection::ProbeOut;

    fn ctx_with_probe<'a>(
        refs: &'a [&'a crate::data::Sample],
        probe: &'a ProbeOut,
        seen: &'a [u64],
        batch: usize,
    ) -> SelectionContext<'a> {
        SelectionContext {
            samples: refs,
            seen_per_class: seen,
            num_classes: 6,
            batch,
            importance: None,
            probe: Some(probe),
            features: None,
            feature_dim: 0,
        }
    }

    #[test]
    fn hl_and_ll_pick_opposite_ends() {
        let cands = candidates(10, 2, 21);
        let refs: Vec<&_> = cands.iter().collect();
        let probe = ProbeOut {
            loss: (0..10).map(|i| i as f32).collect(),
            entropy: vec![0.0; 10],
        };
        let seen = vec![5u64; 6];
        let mut rng = Xoshiro256::seed_from_u64(1);
        let hl = LossBased { high: true }
            .select(&ctx_with_probe(&refs, &probe, &seen, 3), &mut rng)
            .unwrap();
        assert_eq!(hl.indices, vec![9, 8, 7]);
        let ll = LossBased { high: false }
            .select(&ctx_with_probe(&refs, &probe, &seen, 3), &mut rng)
            .unwrap();
        assert_eq!(ll.indices, vec![0, 1, 2]);
    }

    #[test]
    fn entropy_picks_most_uncertain() {
        let cands = candidates(6, 2, 22);
        let refs: Vec<&_> = cands.iter().collect();
        let probe = ProbeOut {
            loss: vec![0.0; 6],
            entropy: vec![0.1, 0.9, 0.5, 0.95, 0.2, 0.3],
        };
        let seen = vec![5u64; 6];
        let mut rng = Xoshiro256::seed_from_u64(2);
        let picks = EntropyBased
            .select(&ctx_with_probe(&refs, &probe, &seen, 2), &mut rng)
            .unwrap();
        assert_eq!(picks.indices, vec![3, 1]);
    }

    #[test]
    fn repdiv_selects_spread_batch() {
        // features on a line; greedy rep+div must not pick near-duplicates
        let cands = candidates(6, 1, 23);
        let mut owned = cands.clone();
        for s in owned.iter_mut() {
            s.label = 0;
        }
        let refs: Vec<&_> = owned.iter().collect();
        let feats: Vec<f32> = vec![
            0.0, 0.0, // 0
            0.1, 0.0, // 1 (near 0)
            5.0, 0.0, // 2
            5.1, 0.0, // 3 (near 2)
            2.5, 0.0, // 4 (center => representative)
            2.6, 0.0, // 5
        ];
        let seen = vec![6u64];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 1,
            batch: 3,
            importance: None,
            probe: None,
            features: Some(&feats),
            feature_dim: 2,
        };
        let mut rng = Xoshiro256::seed_from_u64(3);
        let picks = RepDiv.select(&ctx, &mut rng).unwrap();
        assert_valid_batch(&picks, 6, 3);
        // no two picks from the same near-duplicate pair
        let pair = |i: usize| i / 2;
        let mut pairs: Vec<usize> = picks.indices.iter().map(|&i| pair(i)).collect();
        pairs.sort_unstable();
        pairs.dedup();
        assert_eq!(pairs.len(), 3, "picked near-duplicates: {picks:?}");
    }

    #[test]
    fn missing_evidence_errors() {
        let cands = candidates(4, 2, 24);
        let refs: Vec<&_> = cands.iter().collect();
        let seen = vec![2u64; 6];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 6,
            batch: 2,
            importance: None,
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(4);
        assert!(LossBased { high: true }.select(&ctx, &mut rng).is_err());
        assert!(EntropyBased.select(&ctx, &mut rng).is_err());
        assert!(RepDiv.select(&ctx, &mut rng).is_err());
    }
}
