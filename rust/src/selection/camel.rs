//! Camel (SIGMOD '22) — coreset selection that upper-bounds the gradient
//! distance by the *raw-input* distance to avoid backpropagation: greedily
//! pick the sample minimizing the input-space distance between the
//! selected batch and the full candidate set (k-medoids-style facility
//! location on raw inputs).
//!
//! The paper's critique (§2.3): raw input distance is a poor proxy for
//! gradient distance under modern models, so Camel is efficient but loses
//! the theoretical guarantee — our Fig. 2(b)/Table 1 reproductions show
//! the same.

use super::{SelectedBatch, SelectionContext, SelectionStrategy};
use crate::util::rng::Xoshiro256;
use crate::util::stats;
use crate::Result;

pub struct CamelCoreset;

impl SelectionStrategy for CamelCoreset {
    fn name(&self) -> &'static str {
        "camel"
    }

    fn select(&mut self, ctx: &SelectionContext, _rng: &mut Xoshiro256) -> Result<SelectedBatch> {
        let n = ctx.n();
        let k = ctx.batch.min(n);
        // Facility-location greedy: maximize coverage = Σ_u max_{s∈S} sim(u, s),
        // with sim = -dist². Precompute the pairwise distance matrix once
        // (n ≤ cand_max = 100, cheap).
        let mut d2 = vec![0.0f64; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = stats::dist2(&ctx.samples[i].x, &ctx.samples[j].x);
                d2[i * n + j] = v;
                d2[j * n + i] = v;
            }
        }
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        // best coverage distance per candidate so far (∞ = uncovered)
        let mut best_cover = vec![f64::INFINITY; n];
        for _ in 0..k {
            let mut best_i = usize::MAX;
            let mut best_gain = f64::NEG_INFINITY;
            for i in 0..n {
                if chosen.contains(&i) {
                    continue;
                }
                // gain of adding i: reduction in Σ_u min-dist
                let mut gain = 0.0;
                for u in 0..n {
                    let du = d2[i * n + u];
                    if du < best_cover[u] {
                        // detlint: allow(D004) greedy-cover gain, summed in fixed candidate order
                        gain += (best_cover[u] - du).min(1e18);
                    }
                }
                if gain > best_gain {
                    best_gain = gain;
                    best_i = i;
                }
            }
            chosen.push(best_i);
            for u in 0..n {
                let du = d2[best_i * n + u];
                if du < best_cover[u] {
                    best_cover[u] = du;
                }
            }
        }
        Ok(SelectedBatch::unweighted(chosen))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Sample;
    use crate::selection::testutil::assert_valid_batch;

    fn sample_at(id: u64, x: Vec<f32>) -> Sample {
        Sample::new(id, 0, x)
    }

    #[test]
    fn covers_clusters() {
        // three tight clusters; k=3 must pick one sample per cluster
        let mut samples = Vec::new();
        for (c, center) in [0.0f32, 10.0, 20.0].iter().enumerate() {
            for j in 0..4 {
                samples.push(sample_at(
                    (c * 4 + j) as u64,
                    vec![center + j as f32 * 0.01, 0.0],
                ));
            }
        }
        let refs: Vec<&_> = samples.iter().collect();
        let seen = vec![12u64];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 1,
            batch: 3,
            importance: None,
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(1);
        let picks = CamelCoreset.select(&ctx, &mut rng).unwrap();
        assert_valid_batch(&picks, 12, 3);
        let mut clusters: Vec<usize> = picks.indices.iter().map(|&i| i / 4).collect();
        clusters.sort_unstable();
        clusters.dedup();
        assert_eq!(clusters.len(), 3, "one pick per cluster: {picks:?}");
    }

    #[test]
    fn deterministic() {
        let samples: Vec<Sample> = (0..10)
            .map(|i| sample_at(i, vec![(i as f32 * 1.37).sin(), (i as f32).cos()]))
            .collect();
        let refs: Vec<&_> = samples.iter().collect();
        let seen = vec![10u64];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 1,
            batch: 4,
            importance: None,
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut r1 = Xoshiro256::seed_from_u64(2);
        let mut r2 = Xoshiro256::seed_from_u64(99);
        let a = CamelCoreset.select(&ctx, &mut r1).unwrap();
        let b = CamelCoreset.select(&ctx, &mut r2).unwrap();
        assert_eq!(a.indices, b.indices, "camel must not depend on the RNG");
    }

    #[test]
    fn k_geq_n() {
        let samples: Vec<Sample> = (0..3).map(|i| sample_at(i, vec![i as f32])).collect();
        let refs: Vec<&_> = samples.iter().collect();
        let seen = vec![3u64];
        let ctx = SelectionContext {
            samples: &refs,
            seen_per_class: &seen,
            num_classes: 1,
            batch: 10,
            importance: None,
            probe: None,
            features: None,
            feature_dim: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(3);
        let picks = CamelCoreset.select(&ctx, &mut rng).unwrap();
        assert_valid_batch(&picks, 3, 10);
    }
}
