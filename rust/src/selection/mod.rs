//! Fine-grained data-selection strategies: Titan's C-IS and every baseline
//! the paper compares against (Table 1 columns).
//!
//! A strategy sees one round's *candidate set* plus whatever model-derived
//! evidence its method needs (gradient norms + Gram matrix from the
//! `importance` artifact, per-sample loss/entropy from the `probe`
//! artifact, shallow features), and returns the indices of the training
//! batch. All strategies are deterministic under the round RNG.

pub mod camel;
pub mod cis;
pub mod heuristics;
pub mod importance;
pub mod random;
pub mod variance;

use crate::config::Method;
use crate::data::sample::Sample;
use crate::runtime::model::ImportanceOut;
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// Per-candidate probe scores (from the `probe` artifact).
#[derive(Clone, Debug, Default)]
pub struct ProbeOut {
    /// Per-sample softmax CE loss.
    pub loss: Vec<f32>,
    /// Per-sample output entropy.
    pub entropy: Vec<f32>,
}

/// Everything a strategy may look at for one selection round.
pub struct SelectionContext<'a> {
    /// The candidate samples (post coarse filter, or the whole round's
    /// stream for un-filtered baselines).
    pub samples: &'a [&'a Sample],
    /// Stream class frequencies |S_y| (counts seen so far, per class).
    pub seen_per_class: &'a [u64],
    pub num_classes: usize,
    /// Target batch size |B|.
    pub batch: usize,
    /// Gradient evidence (norms + K), if the method requires it.
    pub importance: Option<&'a ImportanceOut>,
    /// Probe evidence (loss/entropy), if the method requires it.
    pub probe: Option<&'a ProbeOut>,
    /// Shallow features [n * feature_dim] row-major, if available.
    pub features: Option<&'a [f32]>,
    pub feature_dim: usize,
}

impl<'a> SelectionContext<'a> {
    pub fn n(&self) -> usize {
        self.samples.len()
    }

    /// Candidate indices grouped by class label.
    pub fn class_indices(&self) -> Vec<Vec<usize>> {
        let mut by_class = vec![Vec::new(); self.num_classes];
        for (i, s) in self.samples.iter().enumerate() {
            by_class[s.label as usize].push(i);
        }
        by_class
    }

    fn require_importance(&self) -> Result<&'a ImportanceOut> {
        self.importance
            .ok_or_else(|| Error::Other("strategy requires importance evidence".into()))
    }

    fn require_probe(&self) -> Result<&'a ProbeOut> {
        self.probe
            .ok_or_else(|| Error::Other("strategy requires probe evidence".into()))
    }
}

/// A selected batch: candidate indices plus per-sample loss weights.
///
/// Weights implement the paper's unbiasedness correction (Appendix A.2
/// eq. (f): each sample weighted by 1/(probability × size)). Deterministic
/// strategies (RS, the heuristics, Camel) use 1.0 — RS because uniform
/// sampling is already unbiased, the heuristics because their source
/// papers deploy them unweighted (that bias is exactly the paper's §2.3
/// critique). Weights are clipped and mean-normalized (see `make_weights`)
/// to keep the effective learning rate comparable across methods.
#[derive(Clone, Debug)]
pub struct SelectedBatch {
    pub indices: Vec<usize>,
    pub weights: Vec<f32>,
}

impl SelectedBatch {
    pub fn unweighted(indices: Vec<usize>) -> Self {
        let weights = vec![1.0; indices.len()];
        Self { indices, weights }
    }
}

/// Build clipped, mean-normalized inverse-probability weights.
/// `inv_prob[i]` is the raw 1/(P·size) factor for the i-th pick.
pub fn make_weights(inv_prob: &[f64]) -> Vec<f32> {
    if inv_prob.is_empty() {
        return Vec::new();
    }
    const CLIP_LO: f64 = 0.2;
    const CLIP_HI: f64 = 5.0;
    let clipped: Vec<f64> = inv_prob
        .iter()
        .map(|&w| {
            if !w.is_finite() || w <= 0.0 {
                1.0
            } else {
                w.clamp(CLIP_LO, CLIP_HI)
            }
        })
        .collect();
    let mean: f64 = crate::util::stats::sum(&clipped) / clipped.len() as f64;
    clipped.iter().map(|&w| (w / mean) as f32).collect()
}

/// A batch-selection strategy.
pub trait SelectionStrategy: Send {
    fn name(&self) -> &'static str;
    /// Pick `ctx.batch` candidate indices (fewer only if n < batch) with
    /// their unbiasedness weights.
    fn select(&mut self, ctx: &SelectionContext, rng: &mut Xoshiro256)
        -> Result<SelectedBatch>;
}

/// Instantiate the strategy for a method. `Titan` uses the same fine
/// stage as `Cis` (the two differ in the coarse stage + pipeline, which
/// live in the coordinator). `select_threads` parallelizes the C-IS Gram
/// sweep (`RunConfig::select_threads`; results are identical for every
/// value, 1 = no spawned threads); the other strategies ignore it.
pub fn make_strategy(method: Method, select_threads: usize) -> Box<dyn SelectionStrategy> {
    match method {
        Method::Rs => Box::new(random::RandomSelection),
        Method::Is => Box::new(importance::ImportanceSampling),
        Method::Ll => Box::new(heuristics::LossBased { high: false }),
        Method::Hl => Box::new(heuristics::LossBased { high: true }),
        Method::Ce => Box::new(heuristics::EntropyBased),
        Method::Ocs => Box::new(heuristics::RepDiv),
        Method::Camel => Box::new(camel::CamelCoreset),
        Method::Cis | Method::Titan => {
            Box::new(cis::ClassifiedImportanceSampling::new(select_threads))
        }
    }
}

/// Shared post-condition checks used by strategy tests.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::data::synth::{SynthTask, TaskSpec};

    /// Deterministic candidate set with varied labels.
    pub fn candidates(n: usize, classes: usize, seed: u64) -> Vec<Sample> {
        let task = SynthTask::new(TaskSpec::Har, seed, 0.3, 0.1);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|i| task.draw_class(i as u64, (i % classes.min(6)) as u32, &mut rng))
            .collect()
    }

    /// Synthetic ImportanceOut with controllable per-sample gradient
    /// geometry: gradients g_i are 2-D vectors; K_ij = <g_i, g_j>.
    pub fn importance_from_grads(grads: &[(f64, f64)]) -> ImportanceOut {
        let n = grads.len();
        let mut k = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] =
                    (grads[i].0 * grads[j].0 + grads[i].1 * grads[j].1) as f32;
            }
        }
        let norms: Vec<f32> = grads
            .iter()
            .map(|g| ((g.0 * g.0 + g.1 * g.1) as f32).sqrt())
            .collect();
        ImportanceOut {
            norms,
            k,
            n_total: n,
            valid: n,
        }
    }

    pub fn assert_valid_batch(sel: &super::SelectedBatch, n: usize, batch: usize) {
        let picks = &sel.indices;
        assert_eq!(picks.len(), batch.min(n), "batch size");
        assert_eq!(sel.weights.len(), picks.len(), "weights length");
        let mut sorted = picks.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), picks.len(), "duplicates in batch: {picks:?}");
        assert!(picks.iter().all(|&i| i < n), "index out of range");
        assert!(
            sel.weights.iter().all(|&w| w.is_finite() && w > 0.0),
            "bad weights: {:?}",
            sel.weights
        );
    }

    #[test]
    fn make_weights_clips_and_normalizes() {
        let w = super::make_weights(&[0.001, 1.0, 1_000.0, f64::NAN]);
        assert_eq!(w.len(), 4);
        let mean: f32 = w.iter().sum::<f32>() / 4.0;
        assert!((mean - 1.0).abs() < 1e-5, "{w:?}");
        assert!(w[0] < w[1] && w[1] < w[2], "{w:?}");
        assert!(w.iter().all(|&x| x > 0.0));
        assert!(super::make_weights(&[]).is_empty());
    }
}
