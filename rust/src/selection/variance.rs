//! Batch-gradient variance analysis (Theorem 2) — the measurement behind
//! Fig. 5 and the property tests pinning C-IS's optimality.
//!
//! Theorem 2 decomposes the variance of the batch gradient estimator under
//! a (class allocation, intra-class distribution) strategy:
//!
//!   V_B[∇L(B)] = Σ_y α_y (β_y − γ_y),      α_y = |S_y|² / (|S|² |B_y|)
//!   β_y = Σ_{x∈S_y} ‖∇l(x)‖² / (|S_y|² P_y(x)),   γ_y = ‖mean_y ∇l‖²
//!
//! All quantities are computable from the Gram matrix K and the candidate
//! labels. We evaluate the decomposition for RS / IS / C-IS allocations to
//! regenerate Fig. 5(a) and to verify (by property test) that the Lemma-2
//! strategy minimizes the expression over random alternatives.

use crate::runtime::model::ImportanceOut;
use crate::selection::cis::{class_importances, class_summaries, ClassSummary};
use crate::Result;

/// One strategy's (allocation, intra-class distribution) for analysis.
#[derive(Clone, Debug)]
pub struct StrategySpec {
    /// Fractional slots per class (need not be integral — expectation).
    pub alloc: Vec<f64>,
    /// Per class y: P_y(x) over that class's candidate list (sums to 1).
    pub probs: Vec<Vec<f64>>,
}

/// Evaluate Theorem 2's variance for a strategy over the candidates
/// summarized by `summaries` (from [`class_summaries`]). Everything the
/// decomposition needs — the per-candidate diagonal `‖g‖²` included — is
/// carried by the summaries, so no re-walk of the Gram matrix happens
/// here.
pub fn theorem2_variance(summaries: &[ClassSummary], spec: &StrategySpec) -> f64 {
    let total: f64 = summaries.iter().map(|s| s.indices.len() as f64).sum();
    if total == 0.0 {
        return 0.0;
    }
    let mut v = 0.0;
    for (y, s) in summaries.iter().enumerate() {
        let ny = s.indices.len() as f64;
        if s.indices.is_empty() || spec.alloc[y] <= 0.0 {
            continue;
        }
        let alpha = (ny * ny) / (total * total * spec.alloc[y]);
        let mut beta = 0.0;
        for (local, &g2) in s.diag.iter().enumerate() {
            let p = spec.probs[y][local].max(1e-12);
            // detlint: allow(D004) Theorem-2 inner sum in class-local index order, pinned by
            // the variance-decomposition tests
            beta += g2 / (ny * ny * p);
        }
        let gamma = s.mean_grad_norm2;
        // detlint: allow(D004) see above: class-ordered outer sum
        v += alpha * (beta - gamma);
    }
    v
}

/// RS: allocation ∝ class candidate count, uniform within class.
pub fn spec_rs(summaries: &[ClassSummary], batch: usize) -> StrategySpec {
    let total: f64 = summaries.iter().map(|s| s.indices.len() as f64).sum();
    let alloc = summaries
        .iter()
        .map(|s| batch as f64 * s.indices.len() as f64 / total.max(1.0))
        .collect();
    let probs = summaries
        .iter()
        .map(|s| {
            let n = s.indices.len().max(1);
            vec![1.0 / n as f64; s.indices.len()]
        })
        .collect();
    StrategySpec { alloc, probs }
}

/// IS: P(x) ∝ ‖g‖ globally; expected class allocation = B · Σ_{x∈y} P(x);
/// within class, P_y(x) ∝ ‖g‖ (the conditional of the global draw).
pub fn spec_is(summaries: &[ClassSummary], imp: &ImportanceOut, batch: usize) -> StrategySpec {
    let total_norm: f64 = summaries
        .iter()
        .flat_map(|s| s.indices.iter())
        .map(|&i| imp.norms[i] as f64)
        .sum();
    let mut alloc = Vec::with_capacity(summaries.len());
    let mut probs = Vec::with_capacity(summaries.len());
    for s in summaries {
        let class_norm: f64 = s.indices.iter().map(|&i| imp.norms[i] as f64).sum();
        alloc.push(if total_norm > 0.0 {
            batch as f64 * class_norm / total_norm
        } else {
            batch as f64 * s.indices.len() as f64
                / summaries.iter().map(|t| t.indices.len()).sum::<usize>().max(1) as f64
        });
        let p: Vec<f64> = if class_norm > 0.0 {
            s.indices
                .iter()
                .map(|&i| imp.norms[i] as f64 / class_norm)
                .collect()
        } else {
            let n = s.indices.len().max(1);
            vec![1.0 / n as f64; s.indices.len()]
        };
        probs.push(p);
    }
    StrategySpec { alloc, probs }
}

/// C-IS: allocation ∝ I_t(y) (Eq. 2, estimated on the candidates, with
/// the candidate counts standing in for |S_y| so the comparison against
/// RS/IS is apples-to-apples on the same finite set); P_y(x) ∝ ‖g‖.
pub fn spec_cis(summaries: &[ClassSummary], imp: &ImportanceOut, batch: usize) -> StrategySpec {
    // NOTE this is the paper's *continuous* Lemma-2 optimum: Theorem 2's
    // variance expression models |B_y| draws from P_y with replacement, so
    // the allocation here is NOT capped by candidate counts (the runtime
    // C-IS, which samples without replacement, does cap — see cis.rs).
    let seen: Vec<u64> = summaries.iter().map(|s| s.indices.len() as u64).collect();
    let imps = class_importances(summaries, &seen);
    let mass: f64 = imps.iter().sum();
    let alloc: Vec<f64> = if mass > 0.0 {
        imps.iter().map(|&i| batch as f64 * i / mass).collect()
    } else {
        spec_rs(summaries, batch).alloc
    };
    let probs = summaries
        .iter()
        .map(|s| {
            let class_norm: f64 = s.indices.iter().map(|&i| imp.norms[i] as f64).sum();
            if class_norm > 0.0 {
                s.indices
                    .iter()
                    .map(|&i| imp.norms[i] as f64 / class_norm)
                    .collect()
            } else {
                let n = s.indices.len().max(1);
                vec![1.0 / n as f64; s.indices.len()]
            }
        })
        .collect();
    StrategySpec { alloc, probs }
}

/// Convenience: variance for the three Fig. 5(a) strategies at one batch
/// size. Returns (rs, is, cis).
pub fn fig5_variances(
    labels: &[u32],
    imp: &ImportanceOut,
    num_classes: usize,
    batch: usize,
) -> Result<(f64, f64, f64)> {
    let summaries = class_summaries(labels, imp, num_classes);
    let rs = theorem2_variance(&summaries, &spec_rs(&summaries, batch));
    let is = theorem2_variance(&summaries, &spec_is(&summaries, imp, batch));
    let cis = theorem2_variance(&summaries, &spec_cis(&summaries, imp, batch));
    Ok((rs, is, cis))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::testutil::importance_from_grads;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Xoshiro256;

    /// Random gradient geometry: n samples over c classes with per-class
    /// diversity/scale drawn at random.
    fn random_geometry(
        rng: &mut Xoshiro256,
        n: usize,
        c: usize,
    ) -> (Vec<u32>, crate::runtime::model::ImportanceOut) {
        let mut grads = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        let scales: Vec<f64> = (0..c).map(|_| 0.2 + rng.next_f64() * 3.0).collect();
        let spreads: Vec<f64> = (0..c).map(|_| rng.next_f64() * std::f64::consts::PI).collect();
        for i in 0..n {
            let y = i % c;
            let th = spreads[y] * rng.next_f64();
            let r = scales[y] * (0.5 + rng.next_f64());
            grads.push((r * th.cos(), r * th.sin()));
            labels.push(y as u32);
        }
        (labels, importance_from_grads(&grads))
    }

    #[test]
    fn cis_leq_is_leq_some_rs_on_structured_geometry() {
        // Geometry with one diverse-equal-norm class and one concentrated
        // class — where the IS/C-IS gap is provable (Fig. 4 / Fig. 5a).
        let mut grads = Vec::new();
        let mut labels = Vec::new();
        for i in 0..20 {
            let th = i as f64 / 20.0 * 2.0 * std::f64::consts::PI;
            grads.push((th.cos(), th.sin())); // class 0: diverse, ‖g‖=1
            labels.push(0u32);
        }
        for _ in 0..20 {
            grads.push((1.0, 0.0)); // class 1: identical, ‖g‖=1
            labels.push(1u32);
        }
        let imp = importance_from_grads(&grads);
        for batch in [4usize, 10, 20] {
            let (rs, is, cis) = fig5_variances(&labels, &imp, 2, batch).unwrap();
            assert!(cis <= is + 1e-9, "batch {batch}: cis {cis} > is {is}");
            assert!(cis <= rs + 1e-9, "batch {batch}: cis {cis} > rs {rs}");
        }
        // gap widens at smaller batch (the paper's small-batch claim)
        let (_, is4, cis4) = fig5_variances(&labels, &imp, 2, 4).unwrap();
        let (_, is20, cis20) = fig5_variances(&labels, &imp, 2, 20).unwrap();
        assert!(
            (is4 - cis4) > (is20 - cis20),
            "gap small batch {} vs large {}",
            is4 - cis4,
            is20 - cis20
        );
    }

    #[test]
    fn property_cis_minimizes_among_random_allocations() {
        // Lemma 2: on random geometries, no random (allocation, IS-probs)
        // alternative beats the C-IS allocation under Theorem 2.
        forall(
            42,
            40,
            |rng| gen::f64_vec(rng, 3, 3, 0.0, 1.0), // only drives case variety
            |seedvec| {
                let mut rng =
                    Xoshiro256::seed_from_u64((seedvec.iter().sum::<f64>() * 1e6) as u64 + 1);
                let c = 2 + rng.index(3);
                let n = c * (4 + rng.index(8));
                let (labels, imp) = random_geometry(&mut rng, n, c);
                let summaries = class_summaries(&labels, &imp, c);
                let batch = 2 + rng.index(n / 2);
                let cis_spec = spec_cis(&summaries, &imp, batch);
                let v_cis = theorem2_variance(&summaries, &cis_spec);
                // random alternative allocations with the same total mass
                for _ in 0..20 {
                    let mut alloc: Vec<f64> =
                        (0..c).map(|_| 0.05 + rng.next_f64()).collect();
                    let mass: f64 = alloc.iter().sum();
                    for a in alloc.iter_mut() {
                        *a *= batch as f64 / mass;
                    }
                    let alt = StrategySpec {
                        alloc,
                        probs: cis_spec.probs.clone(),
                    };
                    let v_alt = theorem2_variance(&summaries, &alt);
                    if v_alt < v_cis - 1e-6 * v_cis.abs().max(1e-12) {
                        return Err(format!(
                            "random allocation beat C-IS: {v_alt} < {v_cis}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_is_probs_minimize_beta() {
        // Lemma 1 / Cauchy-Schwarz: within a class, P ∝ ‖g‖ minimizes β_y
        // against random intra-class distributions.
        forall(
            7,
            40,
            |rng| gen::f64_vec(rng, 4, 16, 0.1, 5.0),
            |norms| {
                let grads: Vec<(f64, f64)> = norms.iter().map(|&r| (r, 0.0)).collect();
                let imp = importance_from_grads(&grads);
                let labels = vec![0u32; norms.len()];
                let summaries = class_summaries(&labels, &imp, 1);
                let beta = |probs: &[f64]| {
                    let spec = StrategySpec {
                        alloc: vec![1.0],
                        probs: vec![probs.to_vec()],
                    };
                    theorem2_variance(&summaries, &spec)
                };
                let total: f64 = norms.iter().sum();
                let p_is: Vec<f64> = norms.iter().map(|&x| x / total).collect();
                let v_is = beta(&p_is);
                let mut rng = Xoshiro256::seed_from_u64(
                    (norms.iter().map(|x| x * 17.0).sum::<f64>() * 1e3) as u64,
                );
                for _ in 0..20 {
                    let mut p: Vec<f64> = (0..norms.len())
                        .map(|_| 0.01 + rng.next_f64())
                        .collect();
                    let m: f64 = p.iter().sum();
                    for v in p.iter_mut() {
                        *v /= m;
                    }
                    if beta(&p) < v_is - 1e-9 * v_is.abs().max(1e-12) {
                        return Err(format!("random probs beat IS within class"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn variance_decreases_with_batch_size() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (labels, imp) = random_geometry(&mut rng, 40, 4);
        let (rs2, _, cis2) = fig5_variances(&labels, &imp, 4, 2).unwrap();
        let (rs20, _, cis20) = fig5_variances(&labels, &imp, 4, 20).unwrap();
        assert!(rs20 < rs2);
        assert!(cis20 < cis2);
    }

    #[test]
    fn empty_class_is_skipped() {
        let grads = vec![(1.0, 0.0), (0.0, 1.0)];
        let imp = importance_from_grads(&grads);
        let labels = vec![0u32, 0u32];
        let (rs, is, cis) = fig5_variances(&labels, &imp, 3, 1).unwrap();
        assert!(rs.is_finite() && is.is_finite() && cis.is_finite());
    }
}
