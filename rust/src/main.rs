//! `titan` — CLI for the Titan on-device data-selection framework.
//!
//! Subcommands:
//!   run      one training run (model/method/rounds configurable)
//!   exp      regenerate a paper table/figure (see `titan exp list`)
//!   fl       federated-learning run (paper Appendix B)
//!   models   list artifact sets available under --artifacts
//!   verify   execute every artifact against its golden.json
//!
//! Examples:
//!   titan run --model mlp --method titan --rounds 200
//!   titan exp table1 --models all
//!   titan exp fig5a --fast
//!   titan verify

use titan::config::{presets, RunConfig};
use titan::coordinator::{ExecBackend, SessionBuilder};
use titan::exp;
use titan::metrics::write_result;
use titan::runtime::artifact::ArtifactSet;
use titan::util::cli::Args;
use titan::util::logging;
use titan::Result;

fn main() {
    logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(args),
        Some("exp") => cmd_exp(args),
        Some("fl") => cmd_fl(args),
        Some("models") => cmd_models(args),
        Some("verify") => cmd_verify(args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!("titan — two-stage data selection for on-device training (KDD'25 reproduction)");
    println!();
    println!("usage: titan <run|exp|fl|models|verify> [options]");
    println!();
    println!("  run     --model <m> --method <rs|is|ll|hl|ce|ocs|camel|cis|titan>");
    println!("          --rounds N --batch N --candidates N --seed N [--sequential]");
    println!("          [--feature-noise F | --label-noise F]");
    println!("          (any method may run pipelined; --sequential opts out)");
    println!("  exp     <id> [--fast] [--models a,b|all] [--seed N]   (exp list: ids)");
    println!("  fl      --model <m> --method <m> [--fast]");
    println!("  models  [--artifacts DIR]");
    println!("  verify  [--artifacts DIR]   cross-check artifacts vs golden.json");
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg: RunConfig = presets::base(&args.get_str("model", "mlp")).apply_args(args)?;
    cfg.validate()?;
    // pipelining is method-agnostic: any selection method runs through
    // the pipelined backend when requested (pass --sequential to opt out;
    // the old CLI silently downgraded non-Titan methods to sequential)
    let backend = ExecBackend::for_config(&cfg);
    println!("config: {}", cfg.to_json().to_string_compact());
    println!(
        "backend: {}",
        if backend.is_pipelined() { "pipelined" } else { "sequential" }
    );
    let (record, outcomes) = SessionBuilder::new(cfg.clone()).backend(backend).run()?;
    println!(
        "finished {} rounds: final_acc={:.2}% device_time={:.1}s host_time={:.1}s",
        outcomes.len(),
        record.final_accuracy * 100.0,
        record.total_device_ms / 1e3,
        record.total_host_ms / 1e3,
    );
    for p in &record.curve {
        println!(
            "  round {:>5}  loss {:.4}  acc {:.2}%  device {:.1}s",
            p.round,
            p.test_loss,
            p.test_accuracy * 100.0,
            p.device_ms / 1e3
        );
    }
    let name = format!("run_{}_{}", cfg.model, cfg.method.name());
    let path = write_result(&name, &record.to_json())?;
    println!("record -> {}", path.display());
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("list");
    if id == "list" {
        println!("experiments:");
        for (id, desc) in exp::ALL {
            println!("  {id:<8} {desc}");
        }
        return Ok(());
    }
    exp::run(id, args)
}

fn cmd_fl(args: &Args) -> Result<()> {
    exp::fig10::run(args)
}

fn cmd_models(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let models = ArtifactSet::list_models(&dir);
    if models.is_empty() {
        println!("no artifacts under {dir:?} — run `make artifacts`");
        return Ok(());
    }
    for m in models {
        let set = ArtifactSet::discover(&dir, &m)?;
        let meta = &set.meta;
        println!(
            "{:<10} params={:<7} input={:?} classes={} blocks={:?}",
            meta.name, meta.param_count, meta.input_shape, meta.num_classes, meta.block_dims
        );
    }
    Ok(())
}

/// Execute every artifact set against its golden.json — the operational
/// cross-language numerics check (`titan verify`).
fn cmd_verify(args: &Args) -> Result<()> {
    use titan::data::Sample;
    use titan::runtime::model::{ModelRuntime, RuntimeRole};

    let dir = args.get_str("artifacts", "artifacts");
    let models = ArtifactSet::list_models(&dir);
    if models.is_empty() {
        return Err(titan::Error::Artifact(format!(
            "no artifacts under {dir:?} — run `make artifacts`"
        )));
    }
    let det_input = |n: usize, d: usize| -> Vec<Sample> {
        (0..n)
            .map(|i| {
                let x: Vec<f32> = (0..d)
                    .map(|j| ((0.1 * ((i * d + j) as f64 + 1.0)).sin()) as f32)
                    .collect();
                Sample::new(i as u64, 0, x)
            })
            .collect()
    };
    let mut failures = 0;
    for model in &models {
        let mut rt = ModelRuntime::load(&dir, model, RuntimeRole::Full)?;
        let golden = rt.set.golden()?;
        let m = rt.set.meta.clone();
        // train_step
        let mut samples = det_input(m.train_batch, m.input_dim);
        for (i, s) in samples.iter_mut().enumerate() {
            s.label = (i % m.num_classes) as u32;
        }
        let refs: Vec<&Sample> = samples.iter().collect();
        let lr = golden.get("lr")?.as_f64()? as f32;
        let loss = rt.train_step(&refs, lr)? as f64;
        let want = golden.get("loss_step0")?.as_f64()?;
        let ok = (loss - want).abs() < 1e-3 * want.abs().max(1.0);
        println!(
            "{model:<10} train_step loss {loss:.6} vs golden {want:.6}  [{}]",
            if ok { "OK" } else { "FAIL" }
        );
        if !ok {
            failures += 1;
        }
        // importance
        rt.reset_params()?;
        let valid = golden.get("mask_valid")?.as_usize()?;
        let mut cands = det_input(m.cand_max, m.input_dim);
        for (i, s) in cands.iter_mut().enumerate() {
            s.label = (i % m.num_classes) as u32;
        }
        let crefs: Vec<&Sample> = cands.iter().take(valid).collect();
        let imp = rt.importance(&crefs)?;
        let ksum: f64 = imp.k.iter().map(|&v| v as f64).sum();
        let want_k = golden.get("k_sum")?.as_f64()?;
        let ok = (ksum - want_k).abs() < 2e-2 * want_k.abs().max(1.0);
        println!(
            "{model:<10} importance k_sum {ksum:.4} vs golden {want_k:.4}  [{}]",
            if ok { "OK" } else { "FAIL" }
        );
        if !ok {
            failures += 1;
        }
    }
    if failures > 0 {
        Err(titan::Error::Other(format!("{failures} golden checks failed")))
    } else {
        println!("all golden checks passed ({} models)", models.len());
        Ok(())
    }
}
