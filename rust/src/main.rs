//! `titan` — CLI for the Titan on-device data-selection framework.
//!
//! Subcommands:
//!   run      one training run (model/method/rounds configurable)
//!   fleet    N concurrent sessions interleaved by the host scheduler
//!   exp      regenerate a paper table/figure (see `titan exp list`)
//!   fl       federated-learning run (paper Appendix B)
//!   models   list artifact sets available under --artifacts
//!   verify   execute every artifact against its golden.json
//!
//! Examples:
//!   titan run --model mlp --method titan --rounds 200
//!   titan fleet --sessions 4 --methods titan,rs --rounds 50 --policy fewest
//!   titan exp table1 --models all
//!   titan exp fig5a --fast
//!   titan verify

use titan::config::{presets, Method, RunConfig};
use titan::coordinator::{ExecBackend, SessionBuilder};
use titan::exp;
use titan::metrics::{render_table, write_result};
use titan::runtime::artifact::ArtifactSet;
use titan::util::cli::Args;
use titan::util::logging;
use titan::Result;

fn main() {
    logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(args),
        Some("fleet") => cmd_fleet(args),
        Some("exp") => cmd_exp(args),
        Some("fl") => cmd_fl(args),
        Some("models") => cmd_models(args),
        Some("verify") => cmd_verify(args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!("titan — two-stage data selection for on-device training (KDD'25 reproduction)");
    println!();
    println!("usage: titan <run|exp|fl|models|verify> [options]");
    println!();
    println!("  run     --model <m> --method <rs|is|ll|hl|ce|ocs|camel|cis|titan>");
    println!("          --rounds N --batch N --candidates N --seed N [--sequential]");
    println!("          [--select-threads N]  parallel Gram sweep (results identical)");
    println!("          [--feature-noise F | --label-noise F]");
    println!("          [--checkpoint FILE] [--checkpoint-every K]  snapshot every K rounds");
    println!("          [--keep-checkpoints K]  checksummed generations kept (default 1");
    println!("          = the plain single file; >=2 enables torn-write fallback)");
    println!("          [--resume FILE]     restart a killed run from its snapshot");
    println!("          (resume walks the vault newest->oldest past corrupt generations)");
    println!("          [--halt-after N]    stop (resumable) after N rounds, no finish");
    println!("          [--store-bytes N]   byte-budgeted retention store (0 = off)");
    println!("          [--retention score|balanced|reservoir]  eviction policy");
    println!("          [--replay-mix F]    retained fraction of each round (0..1)");
    println!("          (any method may run pipelined; --sequential opts out)");
    println!("  fleet   --sessions N --model <m> --methods a,b --rounds N --seed N");
    println!("          [--policy rr|fewest|staleness] [--sources stream,replay,subset,drift]");
    println!("          [--pipelined]  (methods/sources cycle across the N sessions;");
    println!("          sessions interleave round-by-round on the host scheduler)");
    println!("          [--checkpoint-dir DIR] [--checkpoint-every K]  per-member snapshots");
    println!("          [--keep-checkpoints K]  vault generations per member (default 1)");
    println!("          [--resume DIR]  restart each member at its own saved round");
    println!("          [--fault-seed N] [--crash-rate F] [--transient-rate F]");
    println!("          [--straggler-rate F] [--brownout-rate F] [--corrupt-rate F]");
    println!("          [--torn-rate F] [--bitflip-rate F] [--stale-rate F]");
    println!("          deterministic fault injection per (session, round) cell");
    println!("          [--fault-script \"s:r:kind;...\"]  exact scripted cells, e.g.");
    println!("          \"0:2:torn_write;0:3:crash\" (kinds: crash|transient|");
    println!("          straggler:<slowdown>|brownout:<joules>|corrupt_checkpoint|");
    println!("          torn_write|bit_flip|stale_rename)");
    println!("          [--supervise failfast|isolate|restart[:retries[:backoff[:cap]]]]");
    println!("          what the scheduler does about failures (default failfast;");
    println!("          restart backoff doubles per retry up to cap)");
    println!("          [--host-threads T]  sharded work-stealing host: sessions step");
    println!("          op-by-op across T worker threads; records stay bit-identical");
    println!("          [--store-bytes N] [--retention P] [--replay-mix F]  per-member");
    println!("          retention stores (same flags as run)");
    println!("  exp     <id> [--fast] [--models a,b|all] [--seed N]   (exp list: ids)");
    println!("  fl      --model <m> --method <m> [--fast] [--store-bytes N]");
    println!("          [--checkpoint-dir DIR] [--checkpoint-every N] [--keep-checkpoints K]");
    println!("          [--resume]   vault-backed FL capsules, one per (model, method)");
    println!("  models  [--artifacts DIR]");
    println!("  verify  [--artifacts DIR]   cross-check artifacts vs golden.json");
}

/// `--checkpoint-every` as a validated cadence (`Checkpoint::every`
/// asserts > 0; a bad flag should be a config error, not a panic).
fn checkpoint_cadence(args: &Args) -> Result<usize> {
    let every = args.get_usize("checkpoint-every", 10)?;
    if every == 0 {
        return Err(titan::Error::Config(
            "--checkpoint-every must be > 0".into(),
        ));
    }
    Ok(every)
}

/// `--keep-checkpoints` as a validated vault depth (the vault asserts
/// >= 1; a bad flag should be a config error, not a panic).
fn keep_checkpoints(args: &Args) -> Result<usize> {
    let keep = args.get_usize("keep-checkpoints", 1)?;
    if keep == 0 {
        return Err(titan::Error::Config(
            "--keep-checkpoints must be >= 1".into(),
        ));
    }
    Ok(keep)
}

fn cmd_run(args: &Args) -> Result<()> {
    use std::path::PathBuf;
    use titan::coordinator::session::observers::Checkpoint;
    use titan::coordinator::snapshot::{load_vault_checkpoint, Loaded};
    use titan::coordinator::vault::CheckpointVault;
    use titan::coordinator::StepEvent;

    let keep = keep_checkpoints(args)?;
    // --resume reconstructs the exact config from the snapshot instead of
    // trusting re-typed flags (config flags are ignored on resume; the
    // fingerprint check would reject any drift anyway). The vault walks
    // generations newest→oldest, so a torn newest frame falls back
    // instead of aborting the resume.
    let resume_path = args.get("resume").map(PathBuf::from);
    let (mut cfg, resume_snap, recovery) = match &resume_path {
        Some(path) => {
            let vault = CheckpointVault::new(path.clone(), keep);
            let (loaded, telemetry) = load_vault_checkpoint(&vault)?;
            match loaded {
                Loaded::Resumable(snap) => {
                    if telemetry.degraded() {
                        println!(
                            "degraded resume: generation {} won ({} frames scanned, \
                             {} torn, {} checksum failures, {} rounds lost)",
                            telemetry.generation_used,
                            telemetry.frames_scanned,
                            telemetry.torn_frames,
                            telemetry.crc_failures,
                            telemetry.rounds_lost
                        );
                    }
                    (
                        RunConfig::from_json(&snap.config)?,
                        Some(snap),
                        telemetry.degraded().then_some(telemetry),
                    )
                }
                Loaded::Complete { round, final_accuracy, .. } => {
                    return Err(titan::Error::Config(format!(
                        "{}: run already complete ({round} rounds, final acc {:.2}%) — \
                         delete the checkpoint to start over",
                        path.display(),
                        final_accuracy * 100.0
                    )));
                }
            }
        }
        None => (
            presets::base(&args.get_str("model", "mlp")).apply_args(args)?,
            None,
            None,
        ),
    };
    // --select-threads is a pure perf knob excluded from the snapshot
    // fingerprint, so a resumed run may re-apply it freely
    cfg.select_threads = args.get_usize("select-threads", cfg.select_threads)?;
    cfg.validate()?;
    // pipelining is method-agnostic: any selection method runs through
    // the pipelined backend when requested (pass --sequential to opt out;
    // the old CLI silently downgraded non-Titan methods to sequential)
    let backend = ExecBackend::for_config(&cfg);
    println!("config: {}", cfg.to_json().to_string_compact());
    println!("backend: {}", backend.kind());

    let mut builder = SessionBuilder::new(cfg.clone()).backend(backend);
    // checkpoint to the explicit --checkpoint path, or keep writing the
    // snapshot a resumed run came from
    if let Some(ck) = args.get("checkpoint").map(PathBuf::from).or(resume_path) {
        builder = builder.observe(Checkpoint::every(ck, checkpoint_cadence(args)?).keep(keep));
    }
    if let Some(snap) = resume_snap {
        println!("resuming at round {}", snap.round);
        builder = builder.resume_from_snapshot(*snap);
    }

    // --halt-after N: simulated preemption (the CI resume smoke) — step N
    // rounds, then exit without teardown, leaving the snapshot resumable
    if args.get("halt-after").is_some() {
        let halt = args.get_usize("halt-after", 0)?;
        let mut session = builder.build()?;
        for _ in 0..halt {
            if let StepEvent::Finished(record) = session.step()? {
                println!(
                    "run finished before the halt: final_acc={:.2}%",
                    record.final_accuracy * 100.0
                );
                return Ok(());
            }
        }
        println!(
            "halted after round {} (resume with --resume)",
            session.rounds_completed()
        );
        return Ok(());
    }

    let (mut record, _) = builder.run()?;
    // a degraded resume is part of this run's story: stamp the vault
    // telemetry so the emitted record carries it (clean runs stay
    // byte-identical — no key at all)
    record.recovery = recovery;
    println!(
        "finished {} rounds: final_acc={:.2}% device_time={:.1}s host_time={:.1}s",
        record.round_device_ms.len(),
        record.final_accuracy * 100.0,
        record.total_device_ms / 1e3,
        record.total_host_ms / 1e3,
    );
    for p in &record.curve {
        println!(
            "  round {:>5}  loss {:.4}  acc {:.2}%  device {:.1}s",
            p.round,
            p.test_loss,
            p.test_accuracy * 100.0,
            p.device_ms / 1e3
        );
    }
    let name = format!("run_{}_{}", cfg.model, cfg.method.name());
    let path = write_result(&name, &record.to_json())?;
    println!("record -> {}", path.display());
    Ok(())
}

/// Build one fleet member's `SessionBuilder` from its (validated) config,
/// source kind, and fleet index. Factored out of [`cmd_fleet`] so restart
/// supervision can re-run the exact same construction when it rebuilds a
/// crashed member: every source here derives its randomness from `cfg`
/// fields, so a rebuild is deterministic.
fn fleet_member_builder(cfg: &RunConfig, kind: &str, i: usize) -> Result<SessionBuilder> {
    use titan::coordinator::session::default_source;
    use titan::data::{ClassSubsetSource, DriftSource, ReplaySource, SynthTask};

    let builder = SessionBuilder::new(cfg.clone());
    Ok(match kind {
        "stream" => builder, // the default synthetic stream
        "replay" => {
            let mut stream = default_source(cfg);
            builder.source(ReplaySource::capture(&mut stream, cfg.stream_per_round * 2)?)
        }
        "subset" => {
            let task = SynthTask::for_model(&cfg.model, cfg.seed);
            let c = task.num_classes();
            let k = (c / 2).max(1);
            let classes: Vec<u32> = (0..k).map(|j| ((i + j) % c) as u32).collect();
            builder.source(ClassSubsetSource::new(task, classes, cfg.seed ^ 0xF1EE7)?)
        }
        "drift" => {
            let task = SynthTask::for_model(&cfg.model, cfg.seed);
            let c = task.num_classes();
            // continual shape: uniform mix drifting toward this
            // session's "home" classes over the first half of the run
            let start = vec![1.0; c];
            let end: Vec<f64> = (0..c)
                .map(|y| if y % 2 == i % 2 { 3.0 } else { 0.25 })
                .collect();
            let drift_rounds = (cfg.rounds / 2).max(1);
            let seed = cfg.seed ^ 0xD21F7;
            builder.source(DriftSource::new(task, start, end, drift_rounds, seed)?)
        }
        other => {
            return Err(titan::Error::Config(format!(
                "unknown source kind {other:?} (stream|replay|subset|drift)"
            )))
        }
    })
}

/// Assemble the fleet's fault plan from CLI flags. Returns `None` when no
/// fault flag was given at all, so the default CLI path carries no plan
/// (a zero-rate plan is behaviorally identical, but `None` keeps the
/// record's JSON shape unchanged for existing consumers).
fn fleet_fault_plan(args: &Args) -> Result<Option<titan::fault::FaultPlan>> {
    let mut plan = titan::fault::FaultPlan::new(args.get_u64("fault-seed", 0)?);
    plan.crash_rate = args.get_f64("crash-rate", 0.0)?;
    plan.transient_rate = args.get_f64("transient-rate", 0.0)?;
    plan.straggler_rate = args.get_f64("straggler-rate", 0.0)?;
    plan.brownout_rate = args.get_f64("brownout-rate", 0.0)?;
    plan.corrupt_rate = args.get_f64("corrupt-rate", 0.0)?;
    plan.torn_rate = args.get_f64("torn-rate", 0.0)?;
    plan.bitflip_rate = args.get_f64("bitflip-rate", 0.0)?;
    plan.stale_rate = args.get_f64("stale-rate", 0.0)?;
    // --fault-script "session:round:kind;..." pins exact fault cells —
    // the CI chaos legs script, say, a torn write then a crash, so the
    // recovery path under test is the same on every run
    if let Some(spec) = args.get("fault-script") {
        for cell in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let mut parts = cell.splitn(3, ':');
            let (Some(s), Some(r), Some(kind)) = (parts.next(), parts.next(), parts.next())
            else {
                return Err(titan::Error::Config(format!(
                    "bad --fault-script cell {cell:?} (want session:round:kind)"
                )));
            };
            let session: usize = s.parse().map_err(|_| {
                titan::Error::Config(format!("bad session in --fault-script cell {cell:?}"))
            })?;
            let round: usize = r.parse().map_err(|_| {
                titan::Error::Config(format!("bad round in --fault-script cell {cell:?}"))
            })?;
            plan = plan.script(session, round, titan::fault::FaultKind::parse(kind)?);
        }
    }
    if args.get("fault-seed").is_none() && plan.is_zero() {
        return Ok(None);
    }
    plan.validate()?;
    Ok(Some(plan))
}

/// `titan fleet` — N concurrent device sessions multiplexed on the host
/// scheduler, with methods and data sources cycling per session.
fn cmd_fleet(args: &Args) -> Result<()> {
    use titan::coordinator::host::{parse_policy, FleetBuilder, FleetProgress};
    use titan::fault::{parse_supervision, SupervisionPolicy};

    let n = args.get_usize("sessions", 3)?;
    if n == 0 {
        return Err(titan::Error::Config("--sessions must be > 0".into()));
    }
    let methods: Vec<Method> = args
        .get_list("methods", &["titan", "rs"])
        .iter()
        .map(|m| Method::parse(m))
        .collect::<Result<Vec<_>>>()?;
    if methods.is_empty() {
        return Err(titan::Error::Config("--methods must name at least one method".into()));
    }
    let source_kinds = args.get_list("sources", &["stream", "replay", "subset", "drift"]);
    if source_kinds.is_empty() {
        return Err(titan::Error::Config("--sources must name at least one source".into()));
    }
    let policy = parse_policy(&args.get_str("policy", "rr"))?;
    let supervise = parse_supervision(&args.get_str("supervise", "failfast"))?;
    let fault_plan = fleet_fault_plan(args)?;
    let host_threads = args.get_usize("host-threads", 1)?;
    if host_threads == 0 {
        return Err(titan::Error::Config("--host-threads must be > 0".into()));
    }

    // --resume DIR restarts each member from DIR/<name>.json and keeps
    // checkpointing there (members whose snapshot marks a finished run
    // are skipped); --checkpoint-dir alone enables fresh checkpointing
    // to the same layout. When both are given, the resume dir wins —
    // silently reading snapshots from one directory while writing to
    // another would discard the saved progress the user pointed at.
    let resume_dir = args.get("resume").map(std::path::PathBuf::from);
    let ck_dir = resume_dir
        .clone()
        .or_else(|| args.get("checkpoint-dir").map(std::path::PathBuf::from));
    let ck_every = checkpoint_cadence(args)?;
    if let Some(dir) = &ck_dir {
        std::fs::create_dir_all(dir)?;
    }

    let mut fleet = FleetBuilder::new()
        .policy_boxed(policy)
        .supervise(supervise)
        .host_threads(host_threads)
        // set before members are added: the vault depth is captured per
        // member at registration time
        .keep_checkpoints(keep_checkpoints(args)?)
        .observe(FleetProgress::every(10));
    if let Some(plan) = &fault_plan {
        fleet = fleet.fault_plan(plan.clone());
    }
    // restart supervision needs a way to rebuild a crashed member from
    // scratch; everyone else keeps the plain (factory-free) registration
    // so the default path is exactly what it was
    let restartable = matches!(supervise, SupervisionPolicy::Restart { .. });
    for i in 0..n {
        let method = methods[i % methods.len()];
        let mut cfg = presets::table1(&args.get_str("model", "mlp"), method).apply_args(args)?;
        // fleet-sized default round budget; --rounds still overrides
        cfg.rounds = args.get_usize("rounds", 50)?;
        // distinct streams per session; apply_args already set the base seed
        cfg.seed = cfg.seed.wrapping_add(i as u64);
        // host multiplexing is the point: step bodies run sequentially
        // unless the selector threads are explicitly requested (note:
        // pipelined param-dependent selection is timing-sensitive, so
        // --pipelined trades the solo-identical-records guarantee away)
        cfg.pipeline = args.has_flag("pipelined");
        cfg.validate()?;

        let kind = source_kinds[i % source_kinds.len()].clone();
        let name = format!("s{i}-{}-{kind}", method.name());
        let factory = move || fleet_member_builder(&cfg, &kind, i);
        fleet = match (&ck_dir, restartable) {
            (Some(dir), true) => fleet.session_checkpointed_restartable(
                name.clone(),
                factory,
                dir.join(format!("{name}.json")),
                ck_every,
                resume_dir.is_some(),
            )?,
            (Some(dir), false) => fleet.session_checkpointed(
                name.clone(),
                factory()?,
                dir.join(format!("{name}.json")),
                ck_every,
                resume_dir.is_some(),
            )?,
            (None, true) => fleet.session_restartable(name, factory)?,
            (None, false) => fleet.session(name, factory()?),
        };
    }
    if fleet.is_empty() {
        println!("all fleet sessions already complete — nothing to resume");
        return Ok(());
    }

    let record = fleet.run()?;
    let rows: Vec<Vec<String>> = record
        .names
        .iter()
        .zip(&record.records)
        .zip(&record.session_rounds)
        .zip(&record.statuses)
        .map(|(((name, rec), &rounds), status)| match rec {
            Some(rec) => vec![
                name.clone(),
                rounds.to_string(),
                format!("{:.2}", rec.final_accuracy * 100.0),
                format!("{:.1}", rec.total_device_ms / 1e3),
                format!("{:.0}", rec.energy_j),
                status.label().to_string(),
            ],
            // a quarantined member has no final record
            None => vec![
                name.clone(),
                rounds.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                status.label().to_string(),
            ],
        })
        .collect();
    // surface why each quarantined member was given up on (the table
    // only has room for the status label)
    for (name, status) in record.names.iter().zip(&record.statuses) {
        if let titan::coordinator::SessionStatus::Quarantined { round, reason } = status {
            println!("quarantined {name:?} at round {round}: {reason}");
        }
    }
    println!(
        "fleet: {} sessions ({} finished), policy {}, supervision {}, {} interleaved rounds",
        record.records.len(),
        record.finished(),
        record.policy,
        record.supervision,
        record.rounds_executed
    );
    println!(
        "{}",
        render_table(
            &["session", "rounds", "final_acc_%", "device_s", "energy_J", "status"],
            &rows
        )
    );
    if record.fault_plan.is_some() || record.faults.total() > 0 {
        let f = &record.faults;
        println!(
            "faults: {} injected (crash {}, transient {}, straggler {}, brownout {}, corrupt {}); \
             {} restarts, {} quarantines, {} rounds recovered",
            f.total(),
            f.crashes,
            f.transients,
            f.stragglers,
            f.brownouts,
            f.corruptions,
            f.restarts,
            f.quarantines,
            f.rounds_recovered
        );
    }
    if let Some(r) = &record.recovery {
        println!(
            "recovery: {} frames scanned, {} torn, {} checksum failures, \
             deepest generation used {}, {} rounds lost",
            r.frames_scanned, r.torn_frames, r.crc_failures, r.generation_used, r.rounds_lost
        );
    }
    println!(
        "host: {:.1}s wall, scheduler overhead {:.3} ms/round, {} device ops, {:.1} MiB resident",
        record.total_host_ms / 1e3,
        record.sched_overhead_per_round_ms(),
        record.device_ops,
        record.peak_memory_bytes as f64 / (1024.0 * 1024.0)
    );
    if record.host_threads > 1 {
        for s in &record.shards {
            println!(
                "  shard {}: {} sessions, {} ops, {} rounds, steals in/out {}/{}, \
                 {:.4} ms sched/tick",
                s.shard,
                s.sessions,
                s.ops,
                s.rounds,
                s.steals_in,
                s.steals_out,
                s.sched_overhead_per_tick_ms()
            );
        }
        println!(
            "  {} host threads, {} total steals",
            record.host_threads, record.steals
        );
    }
    let path = write_result("fleet", &record.to_json())?;
    println!("record -> {}", path.display());
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("list");
    if id == "list" {
        println!("experiments:");
        for (id, desc) in exp::ALL {
            println!("  {id:<8} {desc}");
        }
        return Ok(());
    }
    exp::run(id, args)
}

fn cmd_fl(args: &Args) -> Result<()> {
    exp::fig10::run(args)
}

fn cmd_models(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let models = ArtifactSet::list_models(&dir);
    if models.is_empty() {
        println!("no artifacts under {dir:?} — run `make artifacts`");
        return Ok(());
    }
    for m in models {
        let set = ArtifactSet::discover(&dir, &m)?;
        let meta = &set.meta;
        println!(
            "{:<10} params={:<7} input={:?} classes={} blocks={:?}",
            meta.name, meta.param_count, meta.input_shape, meta.num_classes, meta.block_dims
        );
    }
    Ok(())
}

/// Execute every artifact set against its golden.json — the operational
/// cross-language numerics check (`titan verify`).
fn cmd_verify(args: &Args) -> Result<()> {
    use titan::data::Sample;
    use titan::runtime::model::{ModelRuntime, RuntimeRole};

    let dir = args.get_str("artifacts", "artifacts");
    let models = ArtifactSet::list_models(&dir);
    if models.is_empty() {
        return Err(titan::Error::Artifact(format!(
            "no artifacts under {dir:?} — run `make artifacts`"
        )));
    }
    let det_input = |n: usize, d: usize| -> Vec<Sample> {
        (0..n)
            .map(|i| {
                let x: Vec<f32> = (0..d)
                    .map(|j| ((0.1 * ((i * d + j) as f64 + 1.0)).sin()) as f32)
                    .collect();
                Sample::new(i as u64, 0, x)
            })
            .collect()
    };
    let mut failures = 0;
    for model in &models {
        let mut rt = ModelRuntime::load(&dir, model, RuntimeRole::Full)?;
        let golden = rt.set.golden()?;
        let m = rt.set.meta.clone();
        // train_step
        let mut samples = det_input(m.train_batch, m.input_dim);
        for (i, s) in samples.iter_mut().enumerate() {
            s.label = (i % m.num_classes) as u32;
        }
        let refs: Vec<&Sample> = samples.iter().collect();
        let lr = golden.get("lr")?.as_f64()? as f32;
        let loss = rt.train_step(&refs, lr)? as f64;
        let want = golden.get("loss_step0")?.as_f64()?;
        let ok = (loss - want).abs() < 1e-3 * want.abs().max(1.0);
        println!(
            "{model:<10} train_step loss {loss:.6} vs golden {want:.6}  [{}]",
            if ok { "OK" } else { "FAIL" }
        );
        if !ok {
            failures += 1;
        }
        // importance
        rt.reset_params()?;
        let valid = golden.get("mask_valid")?.as_usize()?;
        let mut cands = det_input(m.cand_max, m.input_dim);
        for (i, s) in cands.iter_mut().enumerate() {
            s.label = (i % m.num_classes) as u32;
        }
        let crefs: Vec<&Sample> = cands.iter().take(valid).collect();
        let imp = rt.importance(&crefs)?;
        let ksum: f64 = imp.k.iter().map(|&v| v as f64).sum();
        let want_k = golden.get("k_sum")?.as_f64()?;
        let ok = (ksum - want_k).abs() < 2e-2 * want_k.abs().max(1.0);
        println!(
            "{model:<10} importance k_sum {ksum:.4} vs golden {want_k:.4}  [{}]",
            if ok { "OK" } else { "FAIL" }
        );
        if !ok {
            failures += 1;
        }
    }
    if failures > 0 {
        Err(titan::Error::Other(format!("{failures} golden checks failed")))
    } else {
        println!("all golden checks passed ({} models)", models.len());
        Ok(())
    }
}
