//! Metrics plane: per-run trackers (loss/accuracy curves, time-to-accuracy
//! on both the host clock and the simulated device clock, processing
//! latency) and result emission as JSON/CSV under `results/`.

use std::io::Write;
use std::path::Path;

use crate::retention::RetentionTelemetry;
use crate::util::json::Json;
use crate::util::timer::LatencyRecorder;

/// One point of the training curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub round: usize,
    /// Simulated device wall-clock at this point (ms).
    pub device_ms: f64,
    /// Host wall-clock at this point (ms).
    pub host_ms: f64,
    pub train_loss: f64,
    pub test_loss: f64,
    pub test_accuracy: f64,
}

impl CurvePoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::Num(self.round as f64)),
            ("device_ms", Json::Num(self.device_ms)),
            ("host_ms", Json::Num(self.host_ms)),
            ("train_loss", Json::Num(self.train_loss)),
            ("test_loss", Json::Num(self.test_loss)),
            ("test_accuracy", Json::Num(self.test_accuracy)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<CurvePoint> {
        Ok(CurvePoint {
            round: j.get("round")?.as_usize()?,
            device_ms: j.get("device_ms")?.as_f64()?,
            host_ms: j.get("host_ms")?.as_f64()?,
            train_loss: j.get("train_loss")?.as_f64()?,
            test_loss: j.get("test_loss")?.as_f64()?,
            test_accuracy: j.get("test_accuracy")?.as_f64()?,
        })
    }
}

/// Full record of one training run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub method: String,
    pub model: String,
    pub curve: Vec<CurvePoint>,
    /// Per-streaming-sample processing delay (host ms).
    pub processing_delay: LatencyRecorder,
    /// Per-round realized wall time (device ms).
    pub round_device_ms: Vec<f64>,
    /// Per-round host wall time (ms).
    pub round_host_ms: Vec<f64>,
    pub final_accuracy: f64,
    pub total_device_ms: f64,
    pub total_host_ms: f64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    pub peak_memory_bytes: usize,
    /// Cumulative retention-store telemetry — `Some` only when the run
    /// had a storage budget (`--store-bytes > 0`).
    pub retention: Option<RetentionTelemetry>,
    /// Checkpoint-vault recovery telemetry — `Some` only when the run
    /// resumed degraded (rejected frames, or an older generation / fresh
    /// start winning over a corrupt newest artifact).
    pub recovery: Option<crate::coordinator::vault::RecoveryTelemetry>,
}

impl RunRecord {
    pub fn new(method: &str, model: &str) -> Self {
        Self {
            method: method.to_string(),
            model: model.to_string(),
            ..Default::default()
        }
    }

    /// Device-clock time to first reach `target` accuracy (ms), if ever.
    pub fn time_to_accuracy_device(&self, target: f64) -> Option<f64> {
        self.curve
            .iter()
            .find(|p| p.test_accuracy >= target)
            .map(|p| p.device_ms)
    }

    /// Round index at which `target` accuracy is first reached.
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        self.curve
            .iter()
            .find(|p| p.test_accuracy >= target)
            .map(|p| p.round)
    }

    /// Best accuracy along the curve (robust "final" metric for short runs).
    pub fn best_accuracy(&self) -> f64 {
        crate::util::stats::fold_max(self.curve.iter().map(|p| p.test_accuracy), 0.0)
            .max(self.final_accuracy)
    }

    pub fn to_json(&self) -> Json {
        let curve = Json::Arr(self.curve.iter().map(|p| p.to_json()).collect());
        let mut fields = vec![
            ("method", Json::Str(self.method.clone())),
            ("model", Json::Str(self.model.clone())),
            ("curve", curve),
            ("final_accuracy", Json::Num(self.final_accuracy)),
            ("best_accuracy", Json::Num(self.best_accuracy())),
            ("total_device_ms", Json::Num(self.total_device_ms)),
            ("total_host_ms", Json::Num(self.total_host_ms)),
            (
                "processing_delay_ms",
                Json::obj(vec![
                    ("mean", Json::Num(self.processing_delay.mean_ms())),
                    ("p50", Json::Num(self.processing_delay.percentile_ms(50.0))),
                    ("p99", Json::Num(self.processing_delay.percentile_ms(99.0))),
                    ("count", Json::Num(self.processing_delay.count() as f64)),
                ]),
            ),
            ("energy_j", Json::Num(self.energy_j)),
            ("avg_power_w", Json::Num(self.avg_power_w)),
            ("peak_memory_bytes", Json::Num(self.peak_memory_bytes as f64)),
        ];
        // only retaining runs carry the key, so an unbudgeted run's record
        // stays byte-identical to pre-retention builds
        if let Some(t) = &self.retention {
            fields.push(("retention", t.to_json()));
        }
        // likewise only degraded resumes carry the recovery key: a clean
        // run's record stays byte-identical to pre-vault builds
        if let Some(t) = &self.recovery {
            fields.push(("recovery", t.to_json()));
        }
        Json::obj(fields)
    }
}

/// Write a JSON value under results/, creating the directory. Results
/// are regenerable outputs, so they go through the plain (non-fsynced)
/// durable-io seam rather than the checkpoint vault.
pub fn write_result(name: &str, value: &Json) -> crate::Result<std::path::PathBuf> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    crate::util::durable_io::write_plain(&path, value.to_string_pretty().as_bytes())?;
    Ok(path)
}

/// Write simple CSV rows (first row = header) under results/.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> crate::Result<std::path::PathBuf> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut f = crate::util::durable_io::create_file(&path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(path)
}

/// Render an aligned text table (for stdout experiment summaries).
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let header_cells: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&header_cells, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record_with_curve() -> RunRecord {
        let mut r = RunRecord::new("titan", "mlp");
        for i in 0..5 {
            r.curve.push(CurvePoint {
                round: i * 10,
                device_ms: i as f64 * 100.0,
                host_ms: i as f64 * 10.0,
                train_loss: 2.0 - i as f64 * 0.3,
                test_loss: 2.0 - i as f64 * 0.25,
                test_accuracy: 0.2 + i as f64 * 0.15,
            });
        }
        r.final_accuracy = 0.8;
        r
    }

    #[test]
    fn time_to_accuracy() {
        let r = record_with_curve();
        // accuracy hits 0.5 at i=2 (0.2+0.3)
        assert_eq!(r.time_to_accuracy_device(0.5), Some(200.0));
        assert_eq!(r.rounds_to_accuracy(0.5), Some(20));
        assert_eq!(r.time_to_accuracy_device(0.99), None);
        assert!((r.best_accuracy() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn json_has_curve() {
        let j = record_with_curve().to_json();
        assert_eq!(j.get("curve").unwrap().as_arr().unwrap().len(), 5);
        assert_eq!(j.get("method").unwrap().as_str().unwrap(), "titan");
    }

    #[test]
    fn retention_key_only_for_retaining_runs() {
        let mut r = record_with_curve();
        assert!(!r.to_json().to_string_compact().contains("\"retention\""));
        r.retention = Some(RetentionTelemetry { offers: 9, admits: 4, ..Default::default() });
        let j = r.to_json();
        assert_eq!(j.get("retention").unwrap().get("offers").unwrap().as_usize().unwrap(), 9);
    }

    #[test]
    fn recovery_key_only_for_recovered_runs() {
        use crate::coordinator::vault::RecoveryTelemetry;
        let mut r = record_with_curve();
        assert!(!r.to_json().to_string_compact().contains("\"recovery\""));
        r.recovery = Some(RecoveryTelemetry {
            frames_scanned: 2,
            torn_frames: 1,
            generation_used: 1,
            rounds_lost: 3,
            ..Default::default()
        });
        let j = r.to_json();
        let rec = j.get("recovery").unwrap();
        assert_eq!(rec.get("rounds_lost").unwrap().as_usize().unwrap(), 3);
        assert_eq!(rec.get("generation_used").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn curve_point_json_roundtrip_is_exact() {
        let p = CurvePoint {
            round: 42,
            device_ms: 1234.5678901234,
            host_ms: 0.000123,
            train_loss: 1.75,
            test_loss: 0.1 + 0.2, // a value with no short decimal form
            test_accuracy: 0.73125,
        };
        let text = p.to_json().to_string_compact();
        let q = CurvePoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        // bit-exact: the JSON layer prints shortest-roundtrip f64s
        assert_eq!(p.round, q.round);
        assert_eq!(p.device_ms.to_bits(), q.device_ms.to_bits());
        assert_eq!(p.host_ms.to_bits(), q.host_ms.to_bits());
        assert_eq!(p.train_loss.to_bits(), q.train_loss.to_bits());
        assert_eq!(p.test_loss.to_bits(), q.test_loss.to_bits());
        assert_eq!(p.test_accuracy.to_bits(), q.test_accuracy.to_bits());
    }

    #[test]
    fn table_rendering_aligns() {
        let t = render_table(
            &["method", "acc"],
            &[
                vec!["rs".into(), "0.71".into()],
                vec!["titan".into(), "0.754".into()],
            ],
        );
        assert!(t.contains("method"));
        assert!(t.lines().count() >= 4);
    }

    #[test]
    fn write_outputs() {
        let dir = std::env::temp_dir().join("titan_metrics_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let old = std::env::current_dir().unwrap();
        std::env::set_current_dir(&dir).unwrap();
        let p = write_result("unit", &Json::Num(1.0)).unwrap();
        assert!(p.exists());
        let p = write_csv("unit", &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        assert!(p.exists());
        std::env::set_current_dir(old).unwrap();
    }
}
