//! Coarse-grained data filter — Titan's first stage (§3.3).
//!
//! For every streaming sample the filter extracts shallow-layer features
//! (the `features_b<k>` artifact), scores them against per-class running
//! estimators with `λ·Rep + (1−λ)·Div`, and keeps the best-scoring samples
//! in a capped candidate ring that feeds the fine-grained stage.
//!
//! The running estimators are exactly the paper's two per-class sums:
//! the feature centroid `E[f]` and the mean squared norm `E‖f‖²`, both
//! maintained online (Welford/VecMean).
//!
//! λ = 0.5 reproduces the paper's literal (degenerate) Rep+Div sum — see
//! DESIGN.md §Discrepancies #1; the default is 0.3.

use crate::data::buffer::{Candidate, CandidateBuffer};
use crate::data::sample::Sample;
use crate::util::stats::{VecMean, Welford};
use crate::{Error, Result};

/// Exported coarse-filter state for session checkpoints: the per-class
/// running estimators, the retained candidates and the arrival counter.
/// Restoring it reproduces the filter bit-for-bit (see
/// [`CoarseFilter::restore_state`]).
#[derive(Clone, Debug)]
pub struct FilterState {
    /// Per-class `(count, f64 centroid)` from [`VecMean::state`].
    pub centroid: Vec<(u64, Vec<f64>)>,
    /// Per-class `(n, mean, m2)` from [`Welford::state`].
    pub norm2: Vec<(u64, f64, f64)>,
    /// Ring contents, best-first ([`CandidateBuffer::snapshot`] —
    /// provisional over-admissions included). Empty at round boundaries
    /// (the fine stage drains every round), but carried so mid-round
    /// exports stay faithful.
    pub buffer: Vec<Candidate>,
    /// Buffer cap at export time (re-set from the idle budget every
    /// round; restored for mid-round fidelity).
    pub buffer_cap: usize,
    /// Lazy admission threshold at export time
    /// ([`CandidateBuffer::thresh`]; `None` at round boundaries).
    pub buffer_thresh: Option<f64>,
    /// Total arrivals processed.
    pub processed: u64,
}

/// Per-class running estimators over filter features.
#[derive(Debug)]
pub struct ClassEstimators {
    centroid: Vec<VecMean>,
    norm2: Vec<Welford>,
    dim: usize,
}

impl ClassEstimators {
    pub fn new(num_classes: usize, dim: usize) -> Self {
        Self {
            centroid: (0..num_classes).map(|_| VecMean::new(dim)).collect(),
            norm2: (0..num_classes).map(|_| Welford::new()).collect(),
            dim,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn update(&mut self, label: u32, feat: &[f32]) {
        debug_assert_eq!(feat.len(), self.dim);
        self.centroid[label as usize].push(feat);
        self.norm2[label as usize].push(crate::util::simd::norm2(feat));
    }

    pub fn count(&self, label: u32) -> u64 {
        self.norm2[label as usize].count()
    }

    /// Current class centroid (zeros before any observation). Allocates;
    /// hot paths should use [`ClassEstimators::centroid_ref`] instead.
    pub fn centroid(&self, label: u32) -> Vec<f32> {
        self.centroid[label as usize].mean_f32()
    }

    /// Borrowed view of the current class centroid — zero allocation.
    pub fn centroid_ref(&self, label: u32) -> &[f32] {
        self.centroid[label as usize].mean_slice()
    }

    /// Cached `‖centroid‖²` — zero allocation, no O(dim) recompute.
    pub fn centroid_norm2(&self, label: u32) -> f64 {
        self.centroid[label as usize].mean_norm2()
    }

    /// Current class mean squared feature norm.
    pub fn mean_norm2(&self, label: u32) -> f64 {
        self.norm2[label as usize].mean()
    }
}

/// The coarse filter state: estimators + buffer.
pub struct CoarseFilter {
    pub estimators: ClassEstimators,
    pub buffer: CandidateBuffer,
    lambda: f64,
    processed: u64,
}

impl CoarseFilter {
    pub fn new(num_classes: usize, feature_dim: usize, buffer_cap: usize, lambda: f32) -> Self {
        Self {
            estimators: ClassEstimators::new(num_classes, feature_dim),
            buffer: CandidateBuffer::new(buffer_cap),
            lambda: lambda as f64,
            processed: 0,
        }
    }

    /// Rep+Div score of one sample's features against the current
    /// estimators (the Rust mirror of the `filter_score` Pallas kernel —
    /// used on the host path; the kernel-backed path scores feature chunks
    /// inside the importance graph pipeline).
    ///
    /// Zero heap allocations per call: the centroid is borrowed from the
    /// running estimator and `‖c‖²` comes from its cache. The remaining
    /// O(dim) work — `⟨f, c⟩` and `‖f‖²` — runs through the 8-lane wide
    /// kernels ([`crate::util::simd`]): deterministic and CPU-independent,
    /// and within 1e-12 of [`CoarseFilter::score_ref`] (property-pinned;
    /// the lane-striped sums round differently than the scalar chain, so
    /// the agreement is tight-tolerance, not bitwise).
    pub fn score(&self, label: u32, feat: &[f32]) -> f64 {
        let c = self.estimators.centroid_ref(label);
        let cn2 = self.estimators.centroid_norm2(label);
        let m2 = self.estimators.mean_norm2(label);
        let fn2 = crate::util::simd::norm2(feat);
        let fc = crate::util::simd::dot(feat, c);
        let rep = -(fn2 - 2.0 * fc + cn2);
        let div = fn2 + m2 - 2.0 * fc;
        self.lambda * rep + (1.0 - self.lambda) * div
    }

    /// Scalar reference scorer: materializes the centroid and recomputes
    /// `‖c‖²` from scratch on every call (the pre-optimization path). Kept
    /// as the equivalence oracle for property tests and the old-vs-new
    /// benches; not for production use.
    pub fn score_ref(&self, label: u32, feat: &[f32]) -> f64 {
        let c = self.estimators.centroid(label);
        let m2 = self.estimators.mean_norm2(label);
        let fn2 = crate::util::stats::norm2(feat);
        let cn2 = crate::util::stats::norm2(&c);
        let fc = crate::util::stats::dot(feat, &c);
        let rep = -(fn2 - 2.0 * fc + cn2);
        let div = fn2 + m2 - 2.0 * fc;
        self.lambda * rep + (1.0 - self.lambda) * div
    }

    /// Score a chunk of samples in one pass against the **current**
    /// estimator state (no updates). `feats` is row-major
    /// `[samples.len() × feature_dim]`. Scores are appended to `out`
    /// (cleared first) so a reusable buffer makes the whole pass
    /// allocation-free.
    pub fn score_chunk_into(&self, samples: &[Sample], feats: &[f32], out: &mut Vec<f64>) {
        let dim = self.estimators.dim();
        debug_assert!(feats.len() >= samples.len() * dim, "feature rows short");
        out.clear();
        out.reserve(samples.len());
        for (i, s) in samples.iter().enumerate() {
            out.push(self.score(s.label, &feats[i * dim..(i + 1) * dim]));
        }
    }

    /// Allocating convenience wrapper over
    /// [`CoarseFilter::score_chunk_into`]: one `Vec` per chunk, never per
    /// sample.
    pub fn score_chunk(&self, samples: &[Sample], feats: &[f32]) -> Vec<f64> {
        let mut out = Vec::new();
        self.score_chunk_into(samples, feats, &mut out);
        out
    }

    /// Process one streaming sample given its extracted features:
    /// update estimators, score, offer to the buffer.
    /// Returns the score (for metrics).
    pub fn process(&mut self, sample: Sample, feat: &[f32]) -> f64 {
        // estimators first: the sample itself contributes to its class stats
        self.estimators.update(sample.label, feat);
        let score = self.score(sample.label, feat);
        self.buffer.offer(sample, score);
        self.processed += 1;
        score
    }

    /// Process a whole feature chunk in one pass: for each sample,
    /// update-then-score-then-offer, exactly the semantics of calling
    /// [`CoarseFilter::process`] per sample (each arrival contributes to
    /// its class stats before being scored) but with no per-sample heap
    /// allocation — sample clones only share the `Arc` payload. `feats` is
    /// row-major `[samples.len() × feature_dim]`. This is the coordinator's
    /// streaming entry point.
    pub fn process_chunk(&mut self, samples: &[Sample], feats: &[f32]) {
        let dim = self.estimators.dim();
        debug_assert!(feats.len() >= samples.len() * dim, "feature rows short");
        for (i, s) in samples.iter().enumerate() {
            let f = &feats[i * dim..(i + 1) * dim];
            self.estimators.update(s.label, f);
            let score = self.score(s.label, f);
            self.buffer.offer(s.clone(), score);
        }
        self.processed += samples.len() as u64;
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Drain the buffered candidates (best first) for the fine stage.
    pub fn drain(&mut self) -> Vec<Candidate> {
        self.buffer.drain_sorted()
    }

    /// Drain only the best `k` candidates (the coordinator passes the
    /// artifact's `cand_max` — anything past the importance window was
    /// never selectable) and discard the rest; exactly the first `k`
    /// entries of [`CoarseFilter::drain`], sorting only the winners.
    pub fn drain_top(&mut self, k: usize) -> Vec<Candidate> {
        self.buffer.drain_top(k)
    }

    /// Re-cap the buffer for the next round (idle-resource adaptation,
    /// §3.4: the effective candidate budget follows the idle capacity).
    /// Keeps the best `cap` current entries if shrinking. In-place, and a
    /// same-cap call — the common case under a flat idle trace — returns
    /// without touching ring or threshold.
    pub fn set_buffer_cap(&mut self, cap: usize) {
        self.buffer.set_cap(cap);
    }

    /// Export the filter state for a session checkpoint. Estimator means
    /// are exported as the f64 accumulators, so a restore is bit-identical
    /// (the f32 casts and cached norms are re-derived deterministically).
    pub fn export_state(&self) -> FilterState {
        FilterState {
            centroid: self
                .estimators
                .centroid
                .iter()
                .map(|m| {
                    let (n, mean) = m.state();
                    (n, mean.to_vec())
                })
                .collect(),
            norm2: self.estimators.norm2.iter().map(|w| w.state()).collect(),
            buffer: self.buffer.snapshot(),
            buffer_cap: self.buffer.cap(),
            buffer_thresh: self.buffer.thresh(),
            processed: self.processed,
        }
    }

    /// Restore a state exported by [`CoarseFilter::export_state`] into a
    /// freshly built filter of the same geometry. Errors on class-count or
    /// feature-dim mismatches (a config drift the fingerprint check should
    /// have caught earlier).
    pub fn restore_state(&mut self, st: FilterState) -> Result<()> {
        let classes = self.estimators.centroid.len();
        if st.centroid.len() != classes || st.norm2.len() != classes {
            return Err(Error::Config(format!(
                "filter restore: snapshot has {}/{} classes, filter has {classes}",
                st.centroid.len(),
                st.norm2.len()
            )));
        }
        let dim = self.estimators.dim;
        if let Some((_, mean)) = st.centroid.iter().find(|(_, m)| m.len() != dim) {
            return Err(Error::Config(format!(
                "filter restore: centroid dim {} != feature dim {dim}",
                mean.len()
            )));
        }
        self.estimators.centroid = st
            .centroid
            .into_iter()
            .map(|(n, mean)| VecMean::from_state(n, mean))
            .collect();
        self.estimators.norm2 = st
            .norm2
            .into_iter()
            .map(|(n, mean, m2)| Welford::from_state(n, mean, m2))
            .collect();
        if st.buffer_cap == 0 {
            return Err(Error::Config("filter restore: buffer cap must be positive".into()));
        }
        self.buffer.set_cap(st.buffer_cap);
        self.buffer.restore(st.buffer, st.buffer_thresh)?;
        self.processed = st.processed;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feat_sample(id: u64, label: u32) -> Sample {
        Sample::new(id, label, vec![0.0]) // payload irrelevant here
    }

    #[test]
    fn estimators_track_mean_and_norm() {
        let mut e = ClassEstimators::new(2, 2);
        e.update(0, &[1.0, 0.0]);
        e.update(0, &[3.0, 0.0]);
        e.update(1, &[0.0, 5.0]);
        assert_eq!(e.centroid(0), vec![2.0, 0.0]);
        assert_eq!(e.count(0), 2);
        assert!((e.mean_norm2(0) - 5.0).abs() < 1e-9); // (1 + 9)/2
        assert_eq!(e.centroid(1), vec![0.0, 5.0]);
    }

    #[test]
    fn lambda_half_is_constant_within_class() {
        // the paper's degenerate sum: score independent of the sample
        let mut f = CoarseFilter::new(1, 3, 8, 0.5);
        for i in 0..20 {
            let feat = [i as f32 * 0.1, 1.0, -0.3 * i as f32];
            f.estimators.update(0, &feat);
        }
        let s1 = f.score(0, &[1.0, 2.0, 3.0]);
        let s2 = f.score(0, &[-4.0, 0.0, 10.0]);
        assert!(
            (s1 - s2).abs() < 1e-9 * s1.abs().max(1.0),
            "λ=0.5 must cancel: {s1} vs {s2}"
        );
    }

    #[test]
    fn lambda_weighted_ranks_samples() {
        let mut f = CoarseFilter::new(1, 2, 8, 0.3);
        // estimators centered at origin with unit norms
        for _ in 0..50 {
            f.estimators.update(0, &[1.0, 0.0]);
            f.estimators.update(0, &[-1.0, 0.0]);
        }
        // div-dominant λ=0.3 favors far-from-centroid samples
        let near = f.score(0, &[0.1, 0.0]);
        let far = f.score(0, &[4.0, 0.0]);
        assert!(far > near, "far {far} vs near {near}");
    }

    #[test]
    fn process_fills_buffer_with_top_scores() {
        let mut f = CoarseFilter::new(1, 1, 3, 0.0); // pure diversity
        // warm up estimators so scores are meaningful
        for _ in 0..10 {
            f.estimators.update(0, &[0.0]);
        }
        for i in 0..10 {
            let feat = [i as f32]; // higher i = farther = more diverse
            f.process(feat_sample(i as u64, 0), &feat);
        }
        assert_eq!(f.processed(), 10);
        let drained = f.drain();
        assert_eq!(drained.len(), 3);
        // note: estimators move as samples arrive; top ids are the largest
        let ids: Vec<u64> = drained.iter().map(|c| c.sample.id).collect();
        assert!(ids.contains(&9), "{ids:?}");
        assert!(ids.contains(&8), "{ids:?}");
    }

    /// Deterministic pseudo-random feature rows for the equivalence tests.
    fn rand_feats(rng: &mut crate::util::rng::Xoshiro256, n: usize, dim: usize) -> Vec<f32> {
        (0..n * dim).map(|_| (rng.next_f64() * 4.0 - 2.0) as f32).collect()
    }

    #[test]
    fn property_score_matches_scalar_reference() {
        // the zero-alloc cached path must agree with the allocating
        // from-scratch reference within 1e-12 on arbitrary streams
        crate::util::prop::forall(
            101,
            30,
            |rng| crate::util::prop::gen::f64_vec(rng, 3, 3, 0.0, 1.0),
            |seedvec| {
                let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(
                    (seedvec.iter().sum::<f64>() * 1e6) as u64 + 3,
                );
                let classes = 1 + rng.index(4);
                let dim = 1 + rng.index(32);
                let mut f = CoarseFilter::new(classes, dim, 8, rng.next_f64() as f32);
                for step in 0..60 {
                    let label = rng.index(classes) as u32;
                    let feat = rand_feats(&mut rng, 1, dim);
                    f.estimators.update(label, &feat);
                    if step % 3 == 0 {
                        let probe = rand_feats(&mut rng, 1, dim);
                        let fast = f.score(label, &probe);
                        let slow = f.score_ref(label, &probe);
                        if (fast - slow).abs() > 1e-12 * slow.abs().max(1.0) {
                            return Err(format!("score {fast} != ref {slow}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_score_chunk_matches_scalar_path() {
        crate::util::prop::forall(
            102,
            30,
            |rng| crate::util::prop::gen::f64_vec(rng, 3, 3, 0.0, 1.0),
            |seedvec| {
                let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(
                    (seedvec.iter().sum::<f64>() * 1e6) as u64 + 7,
                );
                let classes = 1 + rng.index(4);
                let dim = 1 + rng.index(16);
                let n = 1 + rng.index(40);
                let mut f = CoarseFilter::new(classes, dim, 8, rng.next_f64() as f32);
                // warm estimators with an independent prefix stream
                for _ in 0..30 {
                    let label = rng.index(classes) as u32;
                    f.estimators.update(label, &rand_feats(&mut rng, 1, dim));
                }
                let samples: Vec<Sample> = (0..n)
                    .map(|i| feat_sample(i as u64, rng.index(classes) as u32))
                    .collect();
                let feats = rand_feats(&mut rng, n, dim);
                let chunked = f.score_chunk(&samples, &feats);
                for (i, s) in samples.iter().enumerate() {
                    let scalar = f.score_ref(s.label, &feats[i * dim..(i + 1) * dim]);
                    if (chunked[i] - scalar).abs() > 1e-12 * scalar.abs().max(1.0) {
                        return Err(format!("chunk[{i}] {} != scalar {scalar}", chunked[i]));
                    }
                }
                Ok(())
            },
        );
    }

    /// Wide-lane remainder coverage: dims off the 8-lane width (1, 7, 9,
    /// 63, 65) drive the chunked scorer against the scalar oracle, and an
    /// empty chunk is a no-op on every observable.
    #[test]
    fn property_wide_lanes_cover_remainder_dims() {
        for &dim in &[1usize, 7, 9, 63, 65] {
            crate::util::prop::forall(
                200 + dim as u64,
                10,
                |rng| crate::util::prop::gen::f64_vec(rng, 3, 3, 0.0, 1.0),
                |seedvec| {
                    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(
                        (seedvec.iter().sum::<f64>() * 1e6) as u64 ^ dim as u64,
                    );
                    let classes = 1 + rng.index(3);
                    let n = 1 + rng.index(24);
                    let mut f = CoarseFilter::new(classes, dim, 8, rng.next_f64() as f32);
                    for _ in 0..20 {
                        let label = rng.index(classes) as u32;
                        f.estimators.update(label, &rand_feats(&mut rng, 1, dim));
                    }
                    let samples: Vec<Sample> = (0..n)
                        .map(|i| feat_sample(i as u64, rng.index(classes) as u32))
                        .collect();
                    let feats = rand_feats(&mut rng, n, dim);
                    let chunked = f.score_chunk(&samples, &feats);
                    for (i, s) in samples.iter().enumerate() {
                        let scalar = f.score_ref(s.label, &feats[i * dim..(i + 1) * dim]);
                        if (chunked[i] - scalar).abs() > 1e-12 * scalar.abs().max(1.0) {
                            return Err(format!(
                                "dim {dim} chunk[{i}] {} != scalar {scalar}",
                                chunked[i]
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn empty_chunk_is_a_no_op() {
        let mut f = CoarseFilter::new(2, 7, 8, 0.3);
        for _ in 0..5 {
            f.estimators.update(0, &[1.0; 7]);
        }
        let before_count = f.estimators.count(0);
        let before_norm = f.estimators.mean_norm2(0);
        assert!(f.score_chunk(&[], &[]).is_empty());
        f.process_chunk(&[], &[]);
        assert_eq!(f.processed(), 0);
        assert_eq!(f.estimators.count(0), before_count);
        assert_eq!(f.estimators.mean_norm2(0), before_norm);
        assert!(f.drain().is_empty());
    }

    #[test]
    fn process_chunk_matches_sequential_process() {
        // same samples through process() one-by-one vs process_chunk():
        // identical buffer contents, scores, estimator state
        let classes = 3;
        let dim = 8;
        let n = 50;
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(55);
        let samples: Vec<Sample> = (0..n)
            .map(|i| feat_sample(i as u64, rng.index(classes) as u32))
            .collect();
        let feats = rand_feats(&mut rng, n, dim);
        let mut seq = CoarseFilter::new(classes, dim, 10, 0.3);
        let mut chunked = CoarseFilter::new(classes, dim, 10, 0.3);
        for chunk in samples.chunks(7).zip(feats.chunks(7 * dim)) {
            chunked.process_chunk(chunk.0, chunk.1);
        }
        for (i, s) in samples.iter().enumerate() {
            seq.process(s.clone(), &feats[i * dim..(i + 1) * dim]);
        }
        assert_eq!(seq.processed(), chunked.processed());
        for y in 0..classes as u32 {
            assert_eq!(seq.estimators.count(y), chunked.estimators.count(y));
            assert_eq!(seq.estimators.centroid_ref(y), chunked.estimators.centroid_ref(y));
            assert_eq!(seq.estimators.mean_norm2(y), chunked.estimators.mean_norm2(y));
        }
        let a = seq.drain();
        let b = chunked.drain();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sample.id, y.sample.id);
            assert_eq!(x.score, y.score);
        }
    }

    #[test]
    fn set_buffer_cap_keeps_best_in_place() {
        let mut f = CoarseFilter::new(1, 1, 8, 0.0);
        for _ in 0..10 {
            f.estimators.update(0, &[0.0]);
        }
        for i in 0..8 {
            let feat = [i as f32];
            f.process(feat_sample(i as u64, 0), &feat);
        }
        f.set_buffer_cap(3);
        assert_eq!(f.buffer.cap(), 3);
        let ids: Vec<u64> = f.drain().iter().map(|c| c.sample.id).collect();
        assert_eq!(ids.len(), 3);
        assert!(ids.contains(&7), "{ids:?}");
    }

    /// Kill-and-restore equivalence at the filter layer: a restored filter
    /// must process the remaining stream bit-identically to one that was
    /// never interrupted.
    #[test]
    fn export_restore_continues_bit_identically() {
        let classes = 3;
        let dim = 6;
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(77);
        let mk_round = |rng: &mut crate::util::rng::Xoshiro256, base: u64| {
            let samples: Vec<Sample> = (0..20)
                .map(|i| feat_sample(base + i as u64, rng.index(classes) as u32))
                .collect();
            let feats = rand_feats(rng, 20, dim);
            (samples, feats)
        };
        let mut live = CoarseFilter::new(classes, dim, 8, 0.3);
        // two completed rounds (process + drain, like the coordinator)
        for r in 0..2u64 {
            let (samples, feats) = mk_round(&mut rng, r * 100);
            live.process_chunk(&samples, &feats);
            let _ = live.drain();
        }
        let state = live.export_state();
        let mut restored = CoarseFilter::new(classes, dim, 8, 0.3);
        restored.restore_state(state).unwrap();
        assert_eq!(restored.processed(), live.processed());
        // round 3 through both: identical scores, buffer contents, drains
        let (samples, feats) = mk_round(&mut rng, 300);
        live.process_chunk(&samples, &feats);
        restored.process_chunk(&samples, &feats);
        let (a, b) = (live.drain(), restored.drain());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sample.id, y.sample.id);
            assert_eq!(x.score, y.score);
        }
        for y in 0..classes as u32 {
            assert_eq!(live.estimators.centroid_ref(y), restored.estimators.centroid_ref(y));
            assert_eq!(live.estimators.mean_norm2(y), restored.estimators.mean_norm2(y));
        }
    }

    #[test]
    fn restore_rejects_mismatched_geometry() {
        let mut f = CoarseFilter::new(2, 4, 8, 0.3);
        let other = CoarseFilter::new(3, 4, 8, 0.3).export_state();
        assert!(f.restore_state(other).is_err());
        let other = CoarseFilter::new(2, 5, 8, 0.3).export_state();
        assert!(f.restore_state(other).is_err());
        let mut ok = CoarseFilter::new(2, 4, 8, 0.3).export_state();
        assert!(f.restore_state(ok.clone()).is_ok());
        ok.buffer_cap = 0;
        assert!(f.restore_state(ok).is_err());
    }

    #[test]
    fn multi_class_scoring_uses_own_class_stats() {
        let mut f = CoarseFilter::new(2, 1, 8, 0.3);
        for _ in 0..20 {
            f.estimators.update(0, &[0.0]);
            f.estimators.update(1, &[10.0]);
        }
        // the same feature scores differently per class (note: a feature
        // equidistant from both centroids would tie — rep and div are both
        // distance-driven — so probe off-center at 2.0)
        let s0 = f.score(0, &[2.0]);
        let s1 = f.score(1, &[2.0]);
        assert_ne!(s0, s1);
    }
}
