//! Coarse-grained data filter — Titan's first stage (§3.3).
//!
//! For every streaming sample the filter extracts shallow-layer features
//! (the `features_b<k>` artifact), scores them against per-class running
//! estimators with `λ·Rep + (1−λ)·Div`, and keeps the best-scoring samples
//! in a capped priority buffer that feeds the fine-grained stage.
//!
//! The running estimators are exactly the paper's two per-class sums:
//! the feature centroid `E[f]` and the mean squared norm `E‖f‖²`, both
//! maintained online (Welford/VecMean).
//!
//! λ = 0.5 reproduces the paper's literal (degenerate) Rep+Div sum — see
//! DESIGN.md §Discrepancies #1; the default is 0.3.

use crate::data::buffer::{Candidate, CandidateBuffer};
use crate::data::sample::Sample;
use crate::util::stats::{VecMean, Welford};

/// Per-class running estimators over filter features.
#[derive(Debug)]
pub struct ClassEstimators {
    centroid: Vec<VecMean>,
    norm2: Vec<Welford>,
    dim: usize,
}

impl ClassEstimators {
    pub fn new(num_classes: usize, dim: usize) -> Self {
        Self {
            centroid: (0..num_classes).map(|_| VecMean::new(dim)).collect(),
            norm2: (0..num_classes).map(|_| Welford::new()).collect(),
            dim,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn update(&mut self, label: u32, feat: &[f32]) {
        debug_assert_eq!(feat.len(), self.dim);
        self.centroid[label as usize].push(feat);
        self.norm2[label as usize].push(crate::util::stats::norm2(feat));
    }

    pub fn count(&self, label: u32) -> u64 {
        self.norm2[label as usize].count()
    }

    /// Current class centroid (zeros before any observation).
    pub fn centroid(&self, label: u32) -> Vec<f32> {
        self.centroid[label as usize].mean_f32()
    }

    /// Current class mean squared feature norm.
    pub fn mean_norm2(&self, label: u32) -> f64 {
        self.norm2[label as usize].mean()
    }
}

/// The coarse filter state: estimators + buffer.
pub struct CoarseFilter {
    pub estimators: ClassEstimators,
    pub buffer: CandidateBuffer,
    lambda: f64,
    processed: u64,
}

impl CoarseFilter {
    pub fn new(num_classes: usize, feature_dim: usize, buffer_cap: usize, lambda: f32) -> Self {
        Self {
            estimators: ClassEstimators::new(num_classes, feature_dim),
            buffer: CandidateBuffer::new(buffer_cap),
            lambda: lambda as f64,
            processed: 0,
        }
    }

    /// Rep+Div score of one sample's features against the current
    /// estimators (the Rust mirror of the `filter_score` Pallas kernel —
    /// used on the host path; the kernel-backed path scores feature chunks
    /// inside the importance graph pipeline).
    pub fn score(&self, label: u32, feat: &[f32]) -> f64 {
        let c = self.estimators.centroid(label);
        let m2 = self.estimators.mean_norm2(label);
        let fn2 = crate::util::stats::norm2(feat);
        let cn2 = crate::util::stats::norm2(&c);
        let fc = crate::util::stats::dot(feat, &c);
        let rep = -(fn2 - 2.0 * fc + cn2);
        let div = fn2 + m2 - 2.0 * fc;
        self.lambda * rep + (1.0 - self.lambda) * div
    }

    /// Process one streaming sample given its extracted features:
    /// update estimators, score, offer to the buffer.
    /// Returns the score (for metrics).
    pub fn process(&mut self, sample: Sample, feat: &[f32]) -> f64 {
        // estimators first: the sample itself contributes to its class stats
        self.estimators.update(sample.label, feat);
        let score = self.score(sample.label, feat);
        self.buffer.offer(sample, score);
        self.processed += 1;
        score
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Drain the buffered candidates (best first) for the fine stage.
    pub fn drain(&mut self) -> Vec<Candidate> {
        self.buffer.drain_sorted()
    }

    /// Re-cap the buffer for the next round (idle-resource adaptation,
    /// §3.4: the effective candidate budget follows the idle capacity).
    /// Keeps the best `cap` current entries if shrinking.
    pub fn set_buffer_cap(&mut self, cap: usize) {
        if cap == self.buffer.cap() {
            return;
        }
        let mut kept = self.buffer.drain_sorted();
        kept.truncate(cap);
        self.buffer = CandidateBuffer::new(cap);
        for c in kept {
            self.buffer.offer(c.sample, c.score);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feat_sample(id: u64, label: u32) -> Sample {
        Sample::new(id, label, vec![0.0]) // payload irrelevant here
    }

    #[test]
    fn estimators_track_mean_and_norm() {
        let mut e = ClassEstimators::new(2, 2);
        e.update(0, &[1.0, 0.0]);
        e.update(0, &[3.0, 0.0]);
        e.update(1, &[0.0, 5.0]);
        assert_eq!(e.centroid(0), vec![2.0, 0.0]);
        assert_eq!(e.count(0), 2);
        assert!((e.mean_norm2(0) - 5.0).abs() < 1e-9); // (1 + 9)/2
        assert_eq!(e.centroid(1), vec![0.0, 5.0]);
    }

    #[test]
    fn lambda_half_is_constant_within_class() {
        // the paper's degenerate sum: score independent of the sample
        let mut f = CoarseFilter::new(1, 3, 8, 0.5);
        for i in 0..20 {
            let feat = [i as f32 * 0.1, 1.0, -0.3 * i as f32];
            f.estimators.update(0, &feat);
        }
        let s1 = f.score(0, &[1.0, 2.0, 3.0]);
        let s2 = f.score(0, &[-4.0, 0.0, 10.0]);
        assert!(
            (s1 - s2).abs() < 1e-9 * s1.abs().max(1.0),
            "λ=0.5 must cancel: {s1} vs {s2}"
        );
    }

    #[test]
    fn lambda_weighted_ranks_samples() {
        let mut f = CoarseFilter::new(1, 2, 8, 0.3);
        // estimators centered at origin with unit norms
        for _ in 0..50 {
            f.estimators.update(0, &[1.0, 0.0]);
            f.estimators.update(0, &[-1.0, 0.0]);
        }
        // div-dominant λ=0.3 favors far-from-centroid samples
        let near = f.score(0, &[0.1, 0.0]);
        let far = f.score(0, &[4.0, 0.0]);
        assert!(far > near, "far {far} vs near {near}");
    }

    #[test]
    fn process_fills_buffer_with_top_scores() {
        let mut f = CoarseFilter::new(1, 1, 3, 0.0); // pure diversity
        // warm up estimators so scores are meaningful
        for _ in 0..10 {
            f.estimators.update(0, &[0.0]);
        }
        for i in 0..10 {
            let feat = [i as f32]; // higher i = farther = more diverse
            f.process(feat_sample(i as u64, 0), &feat);
        }
        assert_eq!(f.processed(), 10);
        let drained = f.drain();
        assert_eq!(drained.len(), 3);
        // note: estimators move as samples arrive; top ids are the largest
        let ids: Vec<u64> = drained.iter().map(|c| c.sample.id).collect();
        assert!(ids.contains(&9), "{ids:?}");
        assert!(ids.contains(&8), "{ids:?}");
    }

    #[test]
    fn multi_class_scoring_uses_own_class_stats() {
        let mut f = CoarseFilter::new(2, 1, 8, 0.3);
        for _ in 0..20 {
            f.estimators.update(0, &[0.0]);
            f.estimators.update(1, &[10.0]);
        }
        // the same feature scores differently per class (note: a feature
        // equidistant from both centroids would tie — rep and div are both
        // distance-driven — so probe off-center at 2.0)
        let s0 = f.score(0, &[2.0]);
        let s1 = f.score(1, &[2.0]);
        assert_ne!(s0, s1);
    }
}
